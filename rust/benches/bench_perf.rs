//! `cargo bench --bench bench_perf` — the §Perf hot-path profile
//! (EXPERIMENTS.md §Perf): per-layer cost decomposition of the serving
//! pipeline.
//!
//! L3 measurements:
//!   * solver-step overhead (coefficients + fused update + RNG) per
//!     sample·step, excluding the model;
//!   * coefficient engine cost alone (exact vs quadrature path);
//!   * batcher throughput;
//!   * end-to-end sampling throughput on the GMM model;
//!   * stepper-driver hot path: monolithic reference loop vs the
//!     incremental stepper driver vs the step-level `BatchRun` scheduler
//!     primitive — asserts all three are bit-identical and emits
//!     `BENCH_stepper.json` (CI uploads it next to the smoke benches).
//! Runtime measurement (needs `make artifacts`):
//!   * artifact execute round-trip (channel + PJRT) for the GMM denoiser
//!     and the fused sa_update kernel vs the native Rust update.
//!
//! Steps/sec + allocations-per-step (counting allocator; this binary
//! installs `testsupport::alloc::CountingAlloc` as the global allocator —
//! note for trajectory readers: every section in this binary therefore
//! pays one relaxed atomic per allocation from this PR on, a small
//! constant bias vs older `BENCH_stepper.json` artifacts):
//!   * monolithic reference loop vs the allocation-free stepper driver on
//!     a model-free solve — the "before/after" of the scratch-arena hot
//!     path, emitted as `BENCH_perf.json` (a CI artifact), including the
//!     headline `stepper_allocs_per_step_after_init` (asserted 0 in
//!     `integration_alloc`, reported here for the perf trajectory).
//!
//! Kernel roofline microbench (always runs, `--quick` shrinks shapes):
//!   * every fused `linalg` kernel measured on the scalar reference tier
//!     vs the dispatched wide tier (docs/KERNELS.md), reporting bytes
//!     moved, FLOPs, kernel calls ("steps") per second, GB/s and GFLOP/s
//!     per tier, plus which dispatch was chosen and why — emitted as the
//!     `kernels` section of `BENCH_perf.json`. The fused kernels are
//!     gated on scalar == wide **bitwise**; the opt-in tolerance lane
//!     (`dot_relaxed`) is gated on its documented error bound. CI fails
//!     the lane if the dispatch or a fallback reason is missing from the
//!     report (no silent scalar fallback).
//!
//! Tracing overhead gate (always runs):
//!   * the `obs` span recorder measured at both load points — a disabled
//!     tracer must keep the stepper hot loop at exactly zero allocations
//!     (hard failure here) and an enabled tracer's `BatchRun` steps/sec
//!     is reported next to the disabled number — emitted as the
//!     `tracing` section of `BENCH_perf.json` and gated by jq in CI.
//!
//! Executor dispatch gate (always runs):
//!   * per-dispatch overhead of the persistent parked worker pool vs the
//!     seed-era scoped spawn-per-dispatch it replaced, at 1/2/4/8 shards
//!     on a trivial task, plus `BatchRun` steps/sec at threads ∈ {1, 4}
//!     gated on bit-identity with the sequential path — emitted as the
//!     `exec` section of `BENCH_perf.json`; CI gates pool < spawn at 4
//!     shards and the identity flag.
//!
//! Router serving tier (always runs):
//!   * end-to-end request latency through an in-process router vs direct
//!     against the worker it fronts, plus the client-visible pause of one
//!     live migration via the `rebalance` verb, both gated on bit-identity
//!     with the direct run — emitted as the `router` section of
//!     `BENCH_perf.json` and jq-gated in CI.
//!
//! Flags: `--quick` (smaller shapes), `--out <path>` for the stepper
//! report (default `BENCH_stepper.json`), `--perf-out <path>` for the
//! steps/sec + allocations report (default `BENCH_perf.json`).

use sadiff::config::{Prediction, SamplerConfig, ServerConfig};
use sadiff::coordinator::batcher::Batcher;
use sadiff::coordinator::engine::BatchRun;
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::{Router, RouterConfig, SampleRequest};
use sadiff::exec::Executor;
use sadiff::gmm::Gmm;
use sadiff::jsonlite::{parse, to_string, Value};
use sadiff::linalg::simd::{self, Dispatch};
use sadiff::models::{EvalCtx, GmmAnalytic, ModelEval};
use sadiff::rng::normal::PhiloxNormal;
use sadiff::schedule::{timesteps, NoiseSchedule, StepSelector};
use sadiff::solvers::coeffs::{coefficients, StepEnds};
use sadiff::solvers::sa::{SaSolver, SaSolverOpts};
use sadiff::solvers::stepper::{make_stepper, Stepper};
use sadiff::solvers::{prior_sample, Grid};
use sadiff::tau::TauFn;
use sadiff::testsupport::alloc::{alloc_count, CountingAlloc};
use sadiff::util::timing::time_it;
use sadiff::workloads;
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// A free model: measures pure coordinator overhead.
struct NullModel {
    dim: usize,
}
impl ModelEval for NullModel {
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval_batch(&self, xs: &[f64], _ctx: &EvalCtx, out: &mut [f64]) {
        out.copy_from_slice(xs);
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_stepper.json")
        .to_string();
    let perf_out_path = args
        .iter()
        .position(|a| a == "--perf-out")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_perf.json")
        .to_string();

    println!("== bench_perf: L3 coordinator hot paths ==\n");
    let sch = NoiseSchedule::vp_linear();

    if !quick {
        l3_sections(&sch);
    }
    stepper_section(quick, &out_path);
    let kernels = kernel_section(quick);
    let tracing = tracing_section(quick);
    let exec = exec_section(quick);
    let router = router_section(quick);
    perf_section(quick, &perf_out_path, kernels, tracing, exec, router);

    // --- 5. Artifact round-trips (skipped without `make artifacts`).
    artifact_section();
}

/// Sections 1–4: the original L3 cost decomposition (skipped by `--quick`,
/// which CI uses to get just the stepper report).
fn l3_sections(sch: &NoiseSchedule) {
    let sch = *sch; // Copy: the section bodies take &sch
    // --- 1. Solver-step overhead (model-free), SDE and ODE configs.
    for (n, dim) in [(64usize, 16usize), (256, 64)] {
        for tau in [1.0f64, 0.0] {
            let m = 32;
            let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
            let model = NullModel { dim };
            let opts = SaSolverOpts {
                predictor_steps: 3,
                corrector_steps: 3,
                prediction: Prediction::Data,
                tau: TauFn::Constant(tau),
            };
            let (mean, min) = time_it(8, || {
                let mut noise = PhiloxNormal::new(1);
                let mut x = vec![0.1; n * dim];
                SaSolver::new(opts.clone()).solve(&model, &grid, &mut x, n, &mut noise);
                std::hint::black_box(&x);
            });
            let per = mean / (m as f64 * n as f64);
            println!(
                "solver-step overhead  n={n:<4} dim={dim:<3} M={m} tau={tau}: {:.3} ms/solve (min {:.3}), {:.1} ns/(sample·step)",
                mean * 1e3,
                min * 1e3,
                per * 1e9
            );
        }
    }

    // --- 2. Coefficient engine alone (exact vs quadrature path).
    let ends = StepEnds {
        lam_s: -1.0,
        lam_t: -0.4,
        alpha_s: 0.55,
        alpha_t: 0.68,
        sigma_s: 0.83,
        sigma_t: 0.73,
    };
    let nodes = [-1.0, -1.6, -2.3];
    for (name, tau) in [
        ("constant(exact)", TauFn::Constant(1.0)),
        ("interval(exact)", TauFn::interval_from_sigma(1.0, 0.05, 1.0)),
        ("linear(quadrature)", TauFn::Linear { a: 0.5, b: 0.1 }),
    ] {
        let (mean, _min) = time_it(5, || {
            for _ in 0..1000 {
                std::hint::black_box(coefficients(&nodes, &ends, &tau, Prediction::Data));
            }
        });
        println!("coefficients[{name:<18}]: {:.2} µs/call", mean * 1e6 / 1000.0);
    }

    // --- 3. Batcher throughput.
    let mk = |id: u64| SampleRequest {
        id,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig::sa_default(),
        n: 4,
        seed: id,
        return_samples: false,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    };
    let (mean, _) = time_it(5, || {
        let mut b = Batcher::new();
        for id in 0..1000 {
            b.push(mk(id));
        }
        while !b.is_empty() {
            std::hint::black_box(b.pop_group(8));
        }
    });
    println!("batcher: {:.0} ns/request (push+group of 1000)", mean * 1e9 / 1000.0);

    // --- 4. End-to-end GMM sampling throughput.
    let wl_gmm = Gmm::structured(16, 5, 2.0, 404);
    let model = GmmAnalytic::new(wl_gmm);
    let cfg = SamplerConfig { nfe: 20, tau: 1.0, ..SamplerConfig::sa_default() };
    let (mean, _) = time_it(5, || {
        std::hint::black_box(sadiff::solvers::run(&model, &sch, &cfg, 256, 3));
    });
    println!(
        "e2e GMM sampling (n=256, dim=16, NFE=20): {:.1} ms  →  {:.0} samples/s",
        mean * 1e3,
        256.0 / mean
    );
}

/// Stepper-driver hot path: the monolithic reference loop vs the
/// incremental stepper driver vs the step-level `BatchRun` primitive the
/// serving scheduler drives. The three must agree bitwise (gate), and the
/// per-step scheduling overhead (BatchRun vs driver) is the number the
/// continuous-batching design pays per step boundary.
fn stepper_section(quick: bool, out_path: &str) {
    let sch = NoiseSchedule::vp_linear();
    let (n, nfe, iters) = if quick { (64usize, 12usize, 3usize) } else { (256, 20, 5) };
    let wl = workloads::latent_analog();
    let cfg = SamplerConfig { nfe, tau: 1.0, ..SamplerConfig::sa_default() };
    let model = GmmAnalytic::new(wl.gmm.clone());
    let exec = Executor::sequential();
    let mk_req = |id: u64| SampleRequest {
        id,
        workload: wl.name.into(),
        model: "gmm".into(),
        cfg: cfg.clone(),
        n,
        seed: 7,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    };

    // Bit-identity gate across the three paths.
    let reference = sadiff::solvers::run_reference(&model, &sch, &cfg, n, 7);
    let driver = sadiff::solvers::run(&model, &sch, &cfg, n, 7);
    let batch = {
        let m: Arc<dyn ModelEval> = Arc::new(GmmAnalytic::new(wl.gmm.clone()));
        let mut br = BatchRun::new(m, &wl, &cfg, vec![mk_req(1)], &exec);
        while !br.step(&exec) {}
        br.finish().remove(0).samples.unwrap()
    };
    let identical = reference.samples == driver.samples && driver.samples == batch;

    let (ref_mean, ref_min) = time_it(iters, || {
        std::hint::black_box(sadiff::solvers::run_reference(&model, &sch, &cfg, n, 7));
    });
    let (drv_mean, drv_min) = time_it(iters, || {
        std::hint::black_box(sadiff::solvers::run(&model, &sch, &cfg, n, 7));
    });
    // Model construction stays outside the timed region (the driver loop
    // reuses a prebuilt model too) so per_step_overhead_us measures only
    // scheduler work.
    let bat_model: Arc<dyn ModelEval> = Arc::new(GmmAnalytic::new(wl.gmm.clone()));
    let (bat_mean, bat_min) = time_it(iters, || {
        let mut br = BatchRun::new(bat_model.clone(), &wl, &cfg, vec![mk_req(1)], &exec);
        while !br.step(&exec) {}
        std::hint::black_box(br.finish());
    });
    // Scheduling overhead the step-level scheduler adds per step boundary.
    let steps = cfg.steps_for_nfe() as f64;
    let per_step_overhead_us = (bat_min - drv_min).max(0.0) / steps * 1e6;
    println!(
        "\nstepper hot path (n={n}, NFE={nfe}): reference {:.2} ms, driver {:.2} ms, \
         batch-run {:.2} ms, per-step scheduling overhead {:.2} µs (identical: {identical})",
        ref_mean * 1e3,
        drv_mean * 1e3,
        bat_mean * 1e3,
        per_step_overhead_us
    );

    let report = Value::obj(vec![
        ("bench", Value::Str("stepper".into())),
        ("lanes", Value::Num(n as f64)),
        ("nfe", Value::Num(nfe as f64)),
        ("reference_mean_ms", Value::Num(ref_mean * 1e3)),
        ("reference_min_ms", Value::Num(ref_min * 1e3)),
        ("driver_mean_ms", Value::Num(drv_mean * 1e3)),
        ("driver_min_ms", Value::Num(drv_min * 1e3)),
        ("batch_run_mean_ms", Value::Num(bat_mean * 1e3)),
        ("batch_run_min_ms", Value::Num(bat_min * 1e3)),
        ("per_step_overhead_us", Value::Num(per_step_overhead_us)),
        ("identical", Value::Bool(identical)),
    ]);
    if let Err(e) = std::fs::write(out_path, format!("{}\n", to_string(&report))) {
        eprintln!("cannot write {out_path}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out_path}");
    if !identical {
        eprintln!("FAIL: stepper paths are not bit-identical to the monolithic reference");
        std::process::exit(1);
    }
}

/// Time one kernel call: `min` over `iters` timed batches of `reps`
/// calls, in nanoseconds per call.
fn bench_ns<F: FnMut()>(iters: usize, reps: usize, mut f: F) -> f64 {
    let (_, min) = time_it(iters, || {
        for _ in 0..reps {
            f();
        }
    });
    min / reps as f64 * 1e9
}

/// Roofline-style kernel microbench: every fused `linalg` kernel on the
/// scalar reference tier vs the dispatched wide tier, at a streaming
/// (cache-exceeding) state size. One kernel call is one solver-step
/// update of a state this size, so calls/sec is reported as
/// `steps_per_sec`. Returns the `kernels` object merged into
/// `BENCH_perf.json` by [`perf_section`].
fn kernel_section(quick: bool) -> Value {
    let wide = simd::dispatch();
    let fallback = simd::fallback_reason();
    println!(
        "\nkernel tier dispatch: {} ({}){}",
        wide.label(),
        simd::dispatch_source(),
        fallback.map(|r| format!(" — fallback: {r}")).unwrap_or_default()
    );

    let n = if quick { 1usize << 16 } else { 1 << 20 };
    let (iters, reps) = if quick { (3usize, 20usize) } else { (5, 60) };
    let nf = n as f64;
    let x: Vec<f64> = (0..n).map(|k| (k as f64 * 0.37).sin() + 0.1).collect();
    let xi: Vec<f64> = (0..n).map(|k| (k as f64 * 0.71).cos()).collect();
    let y0: Vec<f64> = (0..n).map(|k| (k as f64 * 0.11).cos()).collect();
    let max_s = 6usize;
    let hist: Vec<f64> = (0..max_s * n).map(|k| (k as f64 * 0.13).sin()).collect();
    let all_offsets: Vec<usize> = (0..max_s).map(|j| j * n).collect();
    let all_b: Vec<f64> = (0..max_s).map(|j| 0.3 - 0.07 * j as f64).collect();

    let mut rows: Vec<Value> = Vec::new();
    let mut all_identical = true;
    let mut push_row = |name: &str,
                        s: usize,
                        bytes: f64,
                        flops: f64,
                        scalar_ns: f64,
                        wide_ns: f64,
                        identical: bool| {
        println!(
            "kernel {name:<22} s={s}: scalar {:>7.0} ns/step ({:>5.1} GB/s), {} {:>7.0} ns/step \
             ({:>5.1} GB/s), speedup ×{:.2} (identical: {identical})",
            scalar_ns,
            bytes / scalar_ns,
            wide.label(),
            wide_ns,
            bytes / wide_ns,
            scalar_ns / wide_ns
        );
        rows.push(Value::obj(vec![
            ("kernel", Value::Str(name.into())),
            ("history_terms", Value::Num(s as f64)),
            ("bytes_per_call", Value::Num(bytes)),
            ("flops_per_call", Value::Num(flops)),
            ("scalar_ns_per_call", Value::Num(scalar_ns)),
            ("wide_ns_per_call", Value::Num(wide_ns)),
            ("scalar_steps_per_sec", Value::Num(1e9 / scalar_ns)),
            ("wide_steps_per_sec", Value::Num(1e9 / wide_ns)),
            ("scalar_gbps", Value::Num(bytes / scalar_ns)),
            ("wide_gbps", Value::Num(bytes / wide_ns)),
            ("scalar_gflops", Value::Num(flops / scalar_ns)),
            ("wide_gflops", Value::Num(flops / wide_ns)),
            ("speedup", Value::Num(scalar_ns / wide_ns)),
            ("identical", Value::Bool(identical)),
        ]));
    };

    // axpy_into: read x + read/write y = 24 B/elem, 2 flops/elem.
    {
        let mut ys = y0.clone();
        let sc = bench_ns(iters, reps, || {
            simd::axpy_into_with(Dispatch::Scalar, 1e-3, &x, &mut ys)
        });
        let mut yw = y0.clone();
        let wd = bench_ns(iters, reps, || simd::axpy_into_with(wide, 1e-3, &x, &mut yw));
        let mut a = y0.clone();
        simd::axpy_into_with(Dispatch::Scalar, 0.3, &x, &mut a);
        let mut b = y0.clone();
        simd::axpy_into_with(wide, 0.3, &x, &mut b);
        all_identical &= a == b;
        push_row("axpy_into", 0, 24.0 * nf, 2.0 * nf, sc, wd, a == b);
    }

    // sub_into: read a + b, write out = 24 B/elem, 1 flop/elem.
    {
        let mut out = vec![0.0; n];
        let sc = bench_ns(iters, reps, || simd::sub_into_with(Dispatch::Scalar, &x, &xi, &mut out));
        let wd = bench_ns(iters, reps, || simd::sub_into_with(wide, &x, &xi, &mut out));
        let mut a = vec![0.0; n];
        simd::sub_into_with(Dispatch::Scalar, &x, &xi, &mut a);
        let mut b = vec![0.0; n];
        simd::sub_into_with(wide, &x, &xi, &mut b);
        all_identical &= a == b;
        push_row("sub_into", 0, 24.0 * nf, nf, sc, wd, a == b);
    }

    // scale_add: read/write y + read x = 24 B/elem, 3 flops/elem.
    {
        let mut ys = y0.clone();
        let sc = bench_ns(iters, reps, || {
            simd::scale_add_with(Dispatch::Scalar, &mut ys, 0.999, 1e-3, &x)
        });
        let mut yw = y0.clone();
        let wd = bench_ns(iters, reps, || simd::scale_add_with(wide, &mut yw, 0.999, 1e-3, &x));
        let mut a = y0.clone();
        simd::scale_add_with(Dispatch::Scalar, &mut a, 0.9, 0.2, &x);
        let mut b = y0.clone();
        simd::scale_add_with(wide, &mut b, 0.9, 0.2, &x);
        all_identical &= a == b;
        push_row("scale_add", 0, 24.0 * nf, 3.0 * nf, sc, wd, a == b);
    }

    // fma_noise: read/write x + read xi = 24 B/elem, 2 flops/elem.
    {
        let mut ys = y0.clone();
        let sc =
            bench_ns(iters, reps, || simd::fma_noise_with(Dispatch::Scalar, &mut ys, 1e-3, &xi));
        let mut yw = y0.clone();
        let wd = bench_ns(iters, reps, || simd::fma_noise_with(wide, &mut yw, 1e-3, &xi));
        let mut a = y0.clone();
        simd::fma_noise_with(Dispatch::Scalar, &mut a, 0.4, &xi);
        let mut b = y0.clone();
        simd::fma_noise_with(wide, &mut b, 0.4, &xi);
        all_identical &= a == b;
        push_row("fma_noise", 0, 24.0 * nf, 2.0 * nf, sc, wd, a == b);
    }

    // lincomb_into with noise, orders 1–4 (monomorphized reference arms)
    // plus 6 (dynamic/blocked arm): read x + xi + s·hist, write out =
    // (3 + s)·8 B/elem; c0·x + σ·ξ + add + s·(mul + add) = 3 + 2s flops.
    for s in [1usize, 2, 3, 4, 6] {
        let b_s = &all_b[..s];
        let off_s = &all_offsets[..s];
        let noise = Some((0.02, &xi[..]));
        let mut out = vec![0.0; n];
        let sc = bench_ns(iters, reps, || {
            simd::lincomb_into_with(Dispatch::Scalar, 0.9, &x, noise, b_s, &hist, off_s, &mut out)
        });
        let wd = bench_ns(iters, reps, || {
            simd::lincomb_into_with(wide, 0.9, &x, noise, b_s, &hist, off_s, &mut out)
        });
        let mut a = vec![0.0; n];
        simd::lincomb_into_with(Dispatch::Scalar, 0.9, &x, noise, b_s, &hist, off_s, &mut a);
        let mut w = vec![0.0; n];
        simd::lincomb_into_with(wide, 0.9, &x, noise, b_s, &hist, off_s, &mut w);
        all_identical &= a == w;
        let name = format!("lincomb_into_s{s}");
        let bytes = (3.0 + s as f64) * 8.0 * nf;
        push_row(&name, s, bytes, (3.0 + 2.0 * s as f64) * nf, sc, wd, a == w);
    }

    // lincomb_inplace, order 3: read/write x + s·hist = (2 + s)·8 B/elem,
    // 1 + 2s flops.
    {
        let s = 3usize;
        let b_s = &all_b[..s];
        let off_s = &all_offsets[..s];
        let mut ys = y0.clone();
        let sc = bench_ns(iters, reps, || {
            simd::lincomb_inplace_with(Dispatch::Scalar, 0.99, &mut ys, b_s, &hist, off_s)
        });
        let mut yw = y0.clone();
        let wd = bench_ns(iters, reps, || {
            simd::lincomb_inplace_with(wide, 0.99, &mut yw, b_s, &hist, off_s)
        });
        let mut a = y0.clone();
        simd::lincomb_inplace_with(Dispatch::Scalar, 0.9, &mut a, b_s, &hist, off_s);
        let mut w = y0.clone();
        simd::lincomb_inplace_with(wide, 0.9, &mut w, b_s, &hist, off_s);
        all_identical &= a == w;
        push_row("lincomb_inplace_s3", s, (2.0 + s as f64) * 8.0 * nf, 7.0 * nf, sc, wd, a == w);
    }

    // dot_relaxed — the tolerance lane: 16 B/elem read, 2 flops/elem.
    // Not bit-identical by design; gated on the documented error bound.
    {
        let sc = bench_ns(iters, reps, || {
            std::hint::black_box(simd::dot_relaxed_with(Dispatch::Scalar, &x, &xi));
        });
        let wd = bench_ns(iters, reps, || {
            std::hint::black_box(simd::dot_relaxed_with(wide, &x, &xi));
        });
        let exact = simd::dot_relaxed_with(Dispatch::Scalar, &x, &xi);
        let relaxed = simd::dot_relaxed_with(wide, &x, &xi);
        let scale: f64 = x.iter().zip(&xi).map(|(a, b)| (a * b).abs()).sum();
        let in_bound = (relaxed - exact).abs() <= 1e-12 * scale.max(1.0);
        all_identical &= in_bound;
        push_row("dot_relaxed", 0, 16.0 * nf, 2.0 * nf, sc, wd, in_bound);
    }

    if !all_identical {
        eprintln!("FAIL: a wide-tier kernel diverged from the scalar reference tier");
        std::process::exit(1);
    }

    Value::obj(vec![
        ("dispatch", Value::Str(wide.label().into())),
        ("dispatch_source", Value::Str(simd::dispatch_source().into())),
        (
            "fallback",
            match fallback {
                Some(r) => Value::Str(r.into()),
                None => Value::Null,
            },
        ),
        ("block_elems", Value::Num(simd::BLOCK as f64)),
        ("len", Value::Num(nf)),
        ("roofline", Value::Array(rows)),
    ])
}

/// Tracing overhead: the third cross-cutting contract ("observable, and
/// free when off" — docs/OBSERVABILITY.md) measured at both load points.
/// Disabled: a stepper hot loop with a span opened around every step must
/// stay at exactly zero allocations (the zero-allocs-per-step contract
/// with tracing compiled in — hard failure here, and CI re-checks the
/// reported number). Enabled: the `BatchRun` scheduler loop — which
/// records batch_step, shard_step and model_eval spans — timed against
/// the same loop with the recorder off; CI gates the throughput ratio
/// from the `tracing` section of `BENCH_perf.json`.
fn tracing_section(quick: bool) -> Value {
    let sch = NoiseSchedule::vp_linear();
    let (n, dim, nfe, iters) =
        if quick { (64usize, 16usize, 16usize, 3usize) } else { (256, 32, 32, 6) };
    let cfg = SamplerConfig {
        nfe,
        tau: 1.0,
        predictor_steps: 3,
        corrector_steps: 3,
        ..SamplerConfig::sa_default()
    };
    let m = cfg.steps_for_nfe();

    // Disabled-mode allocation gate: the recorder off, a span opened
    // around every step of the allocation-free stepper loop.
    sadiff::obs::trace::stop();
    let model = NullModel { dim };
    let disabled_allocs = {
        let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
        let mut noise = PhiloxNormal::new(13);
        let mut x = prior_sample(&grid, dim, n, &mut noise);
        let mut st = make_stepper(&cfg, &sch);
        st.init(&model, &grid, &mut x, n, &mut noise);
        let before = alloc_count();
        for i in 0..m {
            let _span = sadiff::obs::trace::span("bench_step", "bench");
            st.step(&model, &grid, i, &mut x, n, &mut noise);
        }
        st.finish(&mut x);
        alloc_count() - before
    };

    // Steps/sec with the recorder off vs on, on the BatchRun scheduler
    // loop (the loop the serving workers drive).
    let wl = workloads::latent_analog();
    let bmodel: Arc<dyn ModelEval> = Arc::new(GmmAnalytic::new(wl.gmm.clone()));
    let exec = Executor::sequential();
    let mk_req = |id: u64| SampleRequest {
        id,
        workload: wl.name.into(),
        model: "gmm".into(),
        cfg: cfg.clone(),
        n,
        seed: 13,
        return_samples: false,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    };
    let (_, off_min) = time_it(iters, || {
        let mut br = BatchRun::new(bmodel.clone(), &wl, &cfg, vec![mk_req(1)], &exec);
        while !br.step(&exec) {}
        std::hint::black_box(br.finish());
    });
    sadiff::obs::trace::start();
    let (_, on_min) = time_it(iters, || {
        let mut br = BatchRun::new(bmodel.clone(), &wl, &cfg, vec![mk_req(1)], &exec);
        while !br.step(&exec) {}
        std::hint::black_box(br.finish());
    });
    sadiff::obs::trace::stop();
    let events: usize = sadiff::obs::trace::dump().iter().map(|l| l.events.len()).sum();

    let steps = m as f64;
    let disabled_steps_per_sec = steps / off_min;
    let enabled_steps_per_sec = steps / on_min;
    println!(
        "\ntracing (n={n}, NFE={nfe}): disabled {:.0} steps/s ({disabled_allocs} allocs across \
         the step loop), enabled {:.0} steps/s (×{:.3} of disabled, {events} events captured)",
        disabled_steps_per_sec,
        enabled_steps_per_sec,
        enabled_steps_per_sec / disabled_steps_per_sec
    );
    if disabled_allocs != 0 {
        eprintln!("FAIL: disabled-tracer step loop allocated {disabled_allocs} times (must be 0)");
        std::process::exit(1);
    }
    Value::obj(vec![
        ("lanes", Value::Num(n as f64)),
        ("nfe", Value::Num(nfe as f64)),
        ("steps", Value::Num(steps)),
        ("disabled_steps_per_sec", Value::Num(disabled_steps_per_sec)),
        ("enabled_steps_per_sec", Value::Num(enabled_steps_per_sec)),
        (
            "enabled_over_disabled",
            Value::Num(enabled_steps_per_sec / disabled_steps_per_sec),
        ),
        ("disabled_allocs_per_step", Value::Num(disabled_allocs as f64 / steps)),
        ("events_recorded", Value::Num(events as f64)),
    ])
}

/// The seed-era executor dispatch this PR replaced, reproduced locally
/// as the measurement baseline: one scoped thread per shard beyond the
/// caller's, created and joined on every call. The task assignment
/// (caller runs shard 0, spawned threads run the rest) matches the
/// pool's, so the two sides of the comparison do identical work and
/// differ only in dispatch machinery.
fn legacy_spawn_for_each<T: Send>(items: &mut [T], f: impl Fn(usize, &mut T) + Sync) {
    match items {
        [] => {}
        [only] => f(0, only),
        [head, rest @ ..] => std::thread::scope(|s| {
            for (k, item) in rest.iter_mut().enumerate() {
                let f = &f;
                s.spawn(move || f(k + 1, item));
            }
            f(0, head);
        }),
    }
}

/// Executor dispatch overhead: the persistent parked pool (publish an
/// epoch, wake parked workers, wait out the completion latch) against
/// the legacy scoped spawn-per-dispatch (create + join a thread per
/// shard, every call), on a trivial task so the dispatch machinery is
/// the whole measurement; then `BatchRun` steps/sec at threads ∈ {1, 4}
/// on the GMM model, gated on bit-identity with the sequential path.
/// Returns the `exec` object merged into `BENCH_perf.json` by
/// [`perf_section`]; CI gates pool < spawn at 4 shards and `identical`.
fn exec_section(quick: bool) -> Value {
    let (iters, pool_reps, spawn_reps) =
        if quick { (3usize, 200usize, 20usize) } else { (5, 1000, 50) };

    // --- Per-dispatch overhead at 1/2/4/8 shards. The 8-wide pool is
    // created once (that is the point); the spawn baseline pays its
    // thread creation inside the timed region (that is also the point).
    let pool_exec = Executor::new(8);
    let mut rows: Vec<Value> = Vec::new();
    let mut pool_us_at_4 = f64::NAN;
    let mut spawn_us_at_4 = f64::NAN;
    for shards in [1usize, 2, 4, 8] {
        let mut items = vec![0u64; shards];
        pool_exec.for_each_mut(&mut items, |i, v| *v = i as u64); // warm epoch
        let pool_ns = bench_ns(iters, pool_reps, || {
            pool_exec.for_each_mut(&mut items, |i, v| *v = v.wrapping_add(i as u64 + 1));
        });
        let spawn_ns = bench_ns(iters, spawn_reps, || {
            legacy_spawn_for_each(&mut items, |i, v| *v = v.wrapping_add(i as u64 + 1));
        });
        std::hint::black_box(&items);
        if shards == 4 {
            pool_us_at_4 = pool_ns / 1e3;
            spawn_us_at_4 = spawn_ns / 1e3;
        }
        println!(
            "exec dispatch s={shards}: pool {:>8.0} ns, legacy spawn {:>8.0} ns (spawn/pool ×{:.1})",
            pool_ns,
            spawn_ns,
            spawn_ns / pool_ns
        );
        rows.push(Value::obj(vec![
            ("shards", Value::Num(shards as f64)),
            ("pool_ns_per_dispatch", Value::Num(pool_ns)),
            ("spawn_ns_per_dispatch", Value::Num(spawn_ns)),
            ("spawn_over_pool", Value::Num(spawn_ns / pool_ns)),
        ]));
    }
    drop(pool_exec);

    // --- BatchRun steps/sec at threads ∈ {1, 4}, four requests so the
    // pooled run actually shards, bit-identity gated against sequential.
    let wl = workloads::latent_analog();
    let (n, nfe, br_iters) = if quick { (32usize, 12usize, 3usize) } else { (64, 20, 5) };
    let cfg = SamplerConfig { nfe, tau: 1.0, ..SamplerConfig::sa_default() };
    let reqs: Vec<SampleRequest> = (0..4u64)
        .map(|id| SampleRequest {
            id,
            workload: wl.name.into(),
            model: "gmm".into(),
            cfg: cfg.clone(),
            n,
            seed: 21 + id,
            return_samples: true,
            want_metrics: false,
            preset: None,
            deadline_ms: None,
            priority: 0,
        })
        .collect();
    let model: Arc<dyn ModelEval> = Arc::new(GmmAnalytic::new(wl.gmm.clone()));
    let run_with = |exec: &Executor| {
        let mut br = BatchRun::new(model.clone(), &wl, &cfg, reqs.clone(), exec);
        while !br.step(exec) {}
        br.finish()
    };
    let want = run_with(&Executor::sequential());
    let e1 = Executor::new(1);
    let e4 = Executor::new(4);
    let same = |got: &[sadiff::coordinator::SampleResponse]| {
        want.len() == got.len()
            && want.iter().zip(got).all(|(a, b)| a.samples == b.samples && a.nfe == b.nfe)
    };
    let identical = same(&run_with(&e1)) && same(&run_with(&e4));
    let (_, t1_min) = time_it(br_iters, || {
        std::hint::black_box(run_with(&e1));
    });
    let (_, t4_min) = time_it(br_iters, || {
        std::hint::black_box(run_with(&e4));
    });
    let steps = cfg.steps_for_nfe() as f64;
    println!(
        "exec BatchRun (4 reqs, n={n}, NFE={nfe}): threads=1 {:.0} steps/s, threads=4 {:.0} \
         steps/s (identical: {identical})",
        steps / t1_min,
        steps / t4_min
    );
    if !identical {
        eprintln!("FAIL: pooled BatchRun is not bit-identical to the sequential path");
        std::process::exit(1);
    }

    Value::obj(vec![
        ("dispatch", Value::Array(rows)),
        ("pool_dispatch_us_at_4", Value::Num(pool_us_at_4)),
        ("spawn_dispatch_us_at_4", Value::Num(spawn_us_at_4)),
        ("batchrun_requests", Value::Num(4.0)),
        ("batchrun_lanes", Value::Num(n as f64)),
        ("batchrun_nfe", Value::Num(nfe as f64)),
        ("batchrun_steps_per_sec_t1", Value::Num(steps / t1_min)),
        ("batchrun_steps_per_sec_t4", Value::Num(steps / t4_min)),
        ("identical", Value::Bool(identical)),
    ])
}

/// Router serving tier: end-to-end request latency through the router vs
/// direct against a worker it fronts, plus the client-visible pause of
/// one live migration (the router's `rebalance` verb re-homing an
/// in-flight group at a step boundary). Both paths are gated on
/// bit-identity — a routed or migrated request must return exactly the
/// samples a direct run returns — and the numbers land in the `router`
/// section of `BENCH_perf.json`, jq-gated in CI.
fn router_section(quick: bool) -> Value {
    let worker_cfg = || ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_lane_cap: 1_000_000,
        publish_snapshots: true,
        checkpoint_every: 8,
        ..ServerConfig::default()
    };
    let w0 = Server::bind(worker_cfg()).unwrap().spawn().unwrap();
    let w1 = Server::bind(worker_cfg()).unwrap().spawn().unwrap();
    let worker_addrs = vec![w0.addr.to_string(), w1.addr.to_string()];
    let mut router = Router::bind(RouterConfig {
        addr: "127.0.0.1:0".into(),
        workers: worker_addrs.clone(),
        heartbeat_ms: 25,
        heartbeat_timeout_ms: 500,
        ..RouterConfig::default()
    })
    .unwrap()
    .spawn();
    let router_addr = router.addr().to_string();

    let mk_req = |id: u64, n: usize, nfe: usize| SampleRequest {
        id,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig { nfe, tau: 1.0, ..SamplerConfig::sa_default() },
        n,
        seed: id,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    };

    // --- Request latency: the same request stream direct vs routed. The
    // delta is the router's forwarding cost (re-ticket, placement, one
    // extra TCP hop each way).
    let (reqs_n, n, nfe) = if quick { (10usize, 8usize, 8usize) } else { (40, 16, 12) };
    let run_stream = |addr: &str| -> (f64, f64, Vec<Option<Vec<f64>>>) {
        let mut lat = Vec::with_capacity(reqs_n);
        let mut samples = Vec::with_capacity(reqs_n);
        let mut client = Client::connect(addr).unwrap();
        for id in 0..reqs_n as u64 {
            let t0 = std::time::Instant::now();
            let resp = client.request(&mk_req(id + 1, n, nfe)).unwrap();
            lat.push(t0.elapsed().as_secs_f64() * 1e3);
            assert!(resp.ok, "router bench request failed: {:?}", resp.error);
            samples.push(resp.samples);
        }
        let mean = lat.iter().sum::<f64>() / lat.len() as f64;
        let min = lat.iter().cloned().fold(f64::INFINITY, f64::min);
        (mean, min, samples)
    };
    let (direct_mean, direct_min, direct_samples) = run_stream(&worker_addrs[0]);
    let (routed_mean, routed_min, routed_samples) = run_stream(&router_addr);
    let identical = direct_samples == routed_samples;

    // --- Migration pause: one long solve re-homed mid-flight. The solve
    // is sized off the measured direct throughput so it stays in flight
    // long enough to migrate on fast and slow machines alike; the
    // rebalance reply's pause_ms is the window the group spent detached
    // between a boundary on the source and resumption on the target.
    let rate = (reqs_n * n * nfe) as f64 / (direct_mean * reqs_n as f64).max(1.0);
    let mig_nfe = 100usize;
    let target_ms = if quick { 600.0 } else { 1_200.0 };
    let mig_n = ((rate * target_ms / mig_nfe as f64) as usize).clamp(64, 60_000);
    let mig_req = mk_req(9_001, mig_n, mig_nfe);
    let want = Client::connect(&worker_addrs[0]).unwrap().request(&mig_req).unwrap();
    assert!(want.ok, "migration baseline failed: {:?}", want.error);
    let join = {
        let addr = router_addr.clone();
        let req = mig_req.clone();
        std::thread::spawn(move || Client::connect(&addr).unwrap().request(&req).unwrap())
    };
    let mut pause_ms = 0.0;
    let mut migrated = false;
    let t0 = std::time::Instant::now();
    let mut ctl = Client::connect(&router_addr).unwrap();
    while t0.elapsed() < std::time::Duration::from_secs(10) {
        let reply = ctl.round_trip(r#"{"cmd":"rebalance"}"#).unwrap();
        let v = parse(&reply).unwrap();
        if v.opt_bool("ok", false) {
            pause_ms = v.req_f64("pause_ms").unwrap_or(0.0);
            migrated = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let got = join.join().unwrap();
    let mig_identical = got.ok && got.samples == want.samples;

    println!(
        "\nrouter (2 workers, {reqs_n} reqs of n={n}, NFE={nfe}): direct {direct_mean:.2} ms \
         (min {direct_min:.2}), routed {routed_mean:.2} ms (min {routed_min:.2}), overhead \
         {:+.2} ms; migration of n={mig_n} NFE={mig_nfe}: migrated={migrated}, pause \
         {pause_ms:.1} ms (identical: {identical}/{mig_identical})",
        routed_mean - direct_mean
    );
    if !identical || !mig_identical {
        eprintln!("FAIL: routed or migrated samples diverge from the direct run");
        std::process::exit(1);
    }

    router.shutdown();
    w0.shutdown();
    w1.shutdown();

    Value::obj(vec![
        ("workers", Value::Num(2.0)),
        ("requests", Value::Num(reqs_n as f64)),
        ("lanes", Value::Num(n as f64)),
        ("nfe", Value::Num(nfe as f64)),
        ("direct_mean_ms", Value::Num(direct_mean)),
        ("direct_min_ms", Value::Num(direct_min)),
        ("routed_mean_ms", Value::Num(routed_mean)),
        ("routed_min_ms", Value::Num(routed_min)),
        ("overhead_ms", Value::Num(routed_mean - direct_mean)),
        (
            "migration",
            Value::obj(vec![
                ("lanes", Value::Num(mig_n as f64)),
                ("nfe", Value::Num(mig_nfe as f64)),
                ("migrated", Value::Bool(migrated)),
                ("pause_ms", Value::Num(pause_ms)),
                ("identical", Value::Bool(mig_identical)),
            ]),
        ),
        ("identical", Value::Bool(identical)),
    ])
}

/// Steps/sec + allocations-per-step: the seed-era monolithic loop (the
/// pre-change baseline, retained verbatim as `run_reference`) against the
/// allocation-free stepper driver, on a free model so solver overhead —
/// coefficients, fused updates, RNG, allocator traffic — is the whole
/// measurement. Both numbers land in `BENCH_perf.json` so the perf
/// trajectory records before AND after in the same run, alongside the
/// `kernels` roofline section from [`kernel_section`] and the `exec`
/// dispatch section from [`exec_section`].
fn perf_section(
    quick: bool,
    out_path: &str,
    kernels: Value,
    tracing: Value,
    exec: Value,
    router: Value,
) {
    let sch = NoiseSchedule::vp_linear();
    let (n, dim, nfe, iters) =
        if quick { (64usize, 16usize, 16usize, 3usize) } else { (256, 32, 32, 6) };
    let model = NullModel { dim };
    let cfg = SamplerConfig {
        nfe,
        tau: 1.0,
        predictor_steps: 3,
        corrector_steps: 3,
        ..SamplerConfig::sa_default()
    };
    let m = cfg.steps_for_nfe();

    // Bit-identity gate: the stepper driver must reproduce the monolithic
    // baseline exactly — a perf report comparing diverging computations
    // would be meaningless.
    let want = sadiff::solvers::run_reference(&model, &sch, &cfg, n, 11);
    let got = sadiff::solvers::run(&model, &sch, &cfg, n, 11);
    let identical = want.samples == got.samples && want.nfe == got.nfe;

    let (_, ref_min) = time_it(iters, || {
        std::hint::black_box(sadiff::solvers::run_reference(&model, &sch, &cfg, n, 11));
    });
    let (_, drv_min) = time_it(iters, || {
        std::hint::black_box(sadiff::solvers::run(&model, &sch, &cfg, n, 11));
    });
    let ref_steps_per_sec = m as f64 / ref_min;
    let drv_steps_per_sec = m as f64 / drv_min;

    // Whole-solve allocation counts (grid + prior + init + steps)...
    let ref_allocs = {
        let before = alloc_count();
        std::hint::black_box(sadiff::solvers::run_reference(&model, &sch, &cfg, n, 11));
        alloc_count() - before
    };
    let drv_allocs = {
        let before = alloc_count();
        std::hint::black_box(sadiff::solvers::run(&model, &sch, &cfg, n, 11));
        alloc_count() - before
    };
    // ...and the headline: allocations across the step loop alone, after
    // init (the integration_alloc test asserts this is exactly 0 for all
    // nine solvers; the bench records it in the trajectory).
    let step_allocs = {
        let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
        let mut noise = PhiloxNormal::new(11);
        let mut x = prior_sample(&grid, dim, n, &mut noise);
        let mut st = make_stepper(&cfg, &sch);
        st.init(&model, &grid, &mut x, n, &mut noise);
        let before = alloc_count();
        for i in 0..m {
            st.step(&model, &grid, i, &mut x, n, &mut noise);
        }
        st.finish(&mut x);
        alloc_count() - before
    };

    println!(
        "\nperf (n={n}, dim={dim}, NFE={nfe}): reference {:.0} steps/s, {:.1} allocs/step; \
         stepper {:.0} steps/s, {:.1} allocs/step ({} across the step loop after init); \
         speedup ×{:.2} (identical: {identical})",
        ref_steps_per_sec,
        ref_allocs as f64 / m as f64,
        drv_steps_per_sec,
        drv_allocs as f64 / m as f64,
        step_allocs,
        ref_min / drv_min
    );

    let report = Value::obj(vec![
        ("bench", Value::Str("perf".into())),
        ("lanes", Value::Num(n as f64)),
        ("dim", Value::Num(dim as f64)),
        ("nfe", Value::Num(nfe as f64)),
        ("steps", Value::Num(m as f64)),
        ("reference_min_ms", Value::Num(ref_min * 1e3)),
        ("reference_steps_per_sec", Value::Num(ref_steps_per_sec)),
        ("reference_allocs_per_step", Value::Num(ref_allocs as f64 / m as f64)),
        ("stepper_min_ms", Value::Num(drv_min * 1e3)),
        ("stepper_steps_per_sec", Value::Num(drv_steps_per_sec)),
        ("stepper_allocs_per_step", Value::Num(drv_allocs as f64 / m as f64)),
        ("stepper_allocs_per_step_after_init", Value::Num(step_allocs as f64 / m as f64)),
        ("speedup", Value::Num(ref_min / drv_min)),
        ("identical", Value::Bool(identical)),
        ("kernels", kernels),
        ("tracing", tracing),
        ("exec", exec),
        ("router", router),
    ]);
    if let Err(e) = std::fs::write(out_path, format!("{}\n", to_string(&report))) {
        eprintln!("cannot write {out_path}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out_path}");
    if !identical {
        eprintln!("FAIL: stepper driver is not bit-identical to the monolithic reference");
        std::process::exit(1);
    }
}

/// Artifact round-trips (skipped without `make artifacts`).
fn artifact_section() {
    let dir = std::env::var("SADIFF_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        let host = sadiff::runtime::RuntimeHost::open(&dir).unwrap();
        // GMM artifact execute.
        if let Some(e) = host.registry.entry("gmm_denoiser") {
            let (b, d) = (e.inputs[0][0], e.inputs[0][1]);
            let x = vec![0.1f32; b * d];
            host.execute("gmm_denoiser", vec![x.clone(), vec![0.8], vec![0.6]]).unwrap();
            let (mean, min) = time_it(20, || {
                std::hint::black_box(
                    host.execute("gmm_denoiser", vec![x.clone(), vec![0.8], vec![0.6]]).unwrap(),
                );
            });
            println!(
                "artifact gmm_denoiser execute (B={b}, D={d}): {:.2} ms (min {:.2})",
                mean * 1e3,
                min * 1e3
            );
        }
        // Fused sa_update artifact vs native update.
        if let Some(e) = host.registry.entry("sa_update") {
            let (s, b, d) = (e.inputs[1][0], e.inputs[0][0], e.inputs[0][1]);
            let x = vec![0.1f32; b * d];
            let buf = vec![0.2f32; s * b * d];
            let coeffs = vec![0.3f32; s];
            let scal = vec![0.9f32, 0.1f32];
            let xi = vec![0.0f32; b * d];
            host.execute(
                "sa_update",
                vec![x.clone(), buf.clone(), coeffs.clone(), scal.clone(), xi.clone()],
            )
            .unwrap();
            let (mean_a, _) = time_it(20, || {
                std::hint::black_box(
                    host.execute(
                        "sa_update",
                        vec![x.clone(), buf.clone(), coeffs.clone(), scal.clone(), xi.clone()],
                    )
                    .unwrap(),
                );
            });
            // Native fused update at the same shape.
            let xd: Vec<f64> = x.iter().map(|v| *v as f64).collect();
            let bufd: Vec<f64> = buf.iter().map(|v| *v as f64).collect();
            let xid: Vec<f64> = xi.iter().map(|v| *v as f64).collect();
            let (mean_n, _) = time_it(20, || {
                let mut out = vec![0.0f64; b * d];
                for k in 0..b * d {
                    let mut acc = 0.9 * xd[k] + 0.1 * xid[k];
                    for j in 0..s {
                        acc += 0.3 * bufd[j * b * d + k];
                    }
                    out[k] = acc;
                }
                std::hint::black_box(&out);
            });
            println!(
                "fused update S={s} B={b} D={d}: artifact {:.1} µs vs native {:.1} µs (channel+PJRT overhead dominates at this size)",
                mean_a * 1e6,
                mean_n * 1e6
            );
        }
    } else {
        println!("(artifact benches skipped: run `make artifacts`)");
    }
}
