//! `cargo bench --bench bench_perf` — the §Perf hot-path profile
//! (EXPERIMENTS.md §Perf): per-layer cost decomposition of the serving
//! pipeline.
//!
//! L3 measurements:
//!   * solver-step overhead (coefficients + fused update + RNG) per
//!     sample·step, excluding the model;
//!   * coefficient engine cost alone (exact vs quadrature path);
//!   * batcher throughput;
//!   * end-to-end sampling throughput on the GMM model.
//! Runtime measurement (needs `make artifacts`):
//!   * artifact execute round-trip (channel + PJRT) for the GMM denoiser
//!     and the fused sa_update kernel vs the native Rust update.

use sadiff::config::{Prediction, SamplerConfig};
use sadiff::coordinator::batcher::Batcher;
use sadiff::coordinator::SampleRequest;
use sadiff::gmm::Gmm;
use sadiff::models::{EvalCtx, GmmAnalytic, ModelEval};
use sadiff::rng::normal::PhiloxNormal;
use sadiff::schedule::{timesteps, NoiseSchedule, StepSelector};
use sadiff::solvers::coeffs::{coefficients, StepEnds};
use sadiff::solvers::sa::{SaSolver, SaSolverOpts};
use sadiff::solvers::Grid;
use sadiff::tau::TauFn;
use sadiff::util::timing::time_it;

/// A free model: measures pure coordinator overhead.
struct NullModel {
    dim: usize,
}
impl ModelEval for NullModel {
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval_batch(&self, xs: &[f64], _ctx: &EvalCtx, out: &mut [f64]) {
        out.copy_from_slice(xs);
    }
}

fn main() {
    println!("== bench_perf: L3 coordinator hot paths ==\n");
    let sch = NoiseSchedule::vp_linear();

    // --- 1. Solver-step overhead (model-free), SDE and ODE configs.
    for (n, dim) in [(64usize, 16usize), (256, 64)] {
        for tau in [1.0f64, 0.0] {
            let m = 32;
            let grid = Grid::new(&sch, timesteps(&sch, StepSelector::UniformLambda, m));
            let model = NullModel { dim };
            let opts = SaSolverOpts {
                predictor_steps: 3,
                corrector_steps: 3,
                prediction: Prediction::Data,
                tau: TauFn::Constant(tau),
            };
            let (mean, min) = time_it(8, || {
                let mut noise = PhiloxNormal::new(1);
                let mut x = vec![0.1; n * dim];
                SaSolver::new(opts.clone()).solve(&model, &grid, &mut x, n, &mut noise);
                std::hint::black_box(&x);
            });
            let per = mean / (m as f64 * n as f64);
            println!(
                "solver-step overhead  n={n:<4} dim={dim:<3} M={m} tau={tau}: {:.3} ms/solve (min {:.3}), {:.1} ns/(sample·step)",
                mean * 1e3,
                min * 1e3,
                per * 1e9
            );
        }
    }

    // --- 2. Coefficient engine alone (exact vs quadrature path).
    let ends = StepEnds {
        lam_s: -1.0,
        lam_t: -0.4,
        alpha_s: 0.55,
        alpha_t: 0.68,
        sigma_s: 0.83,
        sigma_t: 0.73,
    };
    let nodes = [-1.0, -1.6, -2.3];
    for (name, tau) in [
        ("constant(exact)", TauFn::Constant(1.0)),
        ("interval(exact)", TauFn::interval_from_sigma(1.0, 0.05, 1.0)),
        ("linear(quadrature)", TauFn::Linear { a: 0.5, b: 0.1 }),
    ] {
        let (mean, _min) = time_it(5, || {
            for _ in 0..1000 {
                std::hint::black_box(coefficients(&nodes, &ends, &tau, Prediction::Data));
            }
        });
        println!("coefficients[{name:<18}]: {:.2} µs/call", mean * 1e6 / 1000.0);
    }

    // --- 3. Batcher throughput.
    let mk = |id: u64| SampleRequest {
        id,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig::sa_default(),
        n: 4,
        seed: id,
        return_samples: false,
        want_metrics: false,
        preset: None,
    };
    let (mean, _) = time_it(5, || {
        let mut b = Batcher::new();
        for id in 0..1000 {
            b.push(mk(id));
        }
        while !b.is_empty() {
            std::hint::black_box(b.pop_group(8));
        }
    });
    println!("batcher: {:.0} ns/request (push+group of 1000)", mean * 1e9 / 1000.0);

    // --- 4. End-to-end GMM sampling throughput.
    let wl_gmm = Gmm::structured(16, 5, 2.0, 404);
    let model = GmmAnalytic::new(wl_gmm);
    let cfg = SamplerConfig { nfe: 20, tau: 1.0, ..SamplerConfig::sa_default() };
    let (mean, _) = time_it(5, || {
        std::hint::black_box(sadiff::solvers::run(&model, &sch, &cfg, 256, 3));
    });
    println!(
        "e2e GMM sampling (n=256, dim=16, NFE=20): {:.1} ms  →  {:.0} samples/s",
        mean * 1e3,
        256.0 / mean
    );

    // --- 5. Artifact round-trips (skipped without `make artifacts`).
    let dir = std::env::var("SADIFF_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        let host = sadiff::runtime::RuntimeHost::open(&dir).unwrap();
        // GMM artifact execute.
        if let Some(e) = host.registry.entry("gmm_denoiser") {
            let (b, d) = (e.inputs[0][0], e.inputs[0][1]);
            let x = vec![0.1f32; b * d];
            host.execute("gmm_denoiser", vec![x.clone(), vec![0.8], vec![0.6]]).unwrap();
            let (mean, min) = time_it(20, || {
                std::hint::black_box(
                    host.execute("gmm_denoiser", vec![x.clone(), vec![0.8], vec![0.6]]).unwrap(),
                );
            });
            println!(
                "artifact gmm_denoiser execute (B={b}, D={d}): {:.2} ms (min {:.2})",
                mean * 1e3,
                min * 1e3
            );
        }
        // Fused sa_update artifact vs native update.
        if let Some(e) = host.registry.entry("sa_update") {
            let (s, b, d) = (e.inputs[1][0], e.inputs[0][0], e.inputs[0][1]);
            let x = vec![0.1f32; b * d];
            let buf = vec![0.2f32; s * b * d];
            let coeffs = vec![0.3f32; s];
            let scal = vec![0.9f32, 0.1f32];
            let xi = vec![0.0f32; b * d];
            host.execute(
                "sa_update",
                vec![x.clone(), buf.clone(), coeffs.clone(), scal.clone(), xi.clone()],
            )
            .unwrap();
            let (mean_a, _) = time_it(20, || {
                std::hint::black_box(
                    host.execute(
                        "sa_update",
                        vec![x.clone(), buf.clone(), coeffs.clone(), scal.clone(), xi.clone()],
                    )
                    .unwrap(),
                );
            });
            // Native fused update at the same shape.
            let xd: Vec<f64> = x.iter().map(|v| *v as f64).collect();
            let bufd: Vec<f64> = buf.iter().map(|v| *v as f64).collect();
            let xid: Vec<f64> = xi.iter().map(|v| *v as f64).collect();
            let (mean_n, _) = time_it(20, || {
                let mut out = vec![0.0f64; b * d];
                for k in 0..b * d {
                    let mut acc = 0.9 * xd[k] + 0.1 * xid[k];
                    for j in 0..s {
                        acc += 0.3 * bufd[j * b * d + k];
                    }
                    out[k] = acc;
                }
                std::hint::black_box(&out);
            });
            println!(
                "fused update S={s} B={b} D={d}: artifact {:.1} µs vs native {:.1} µs (channel+PJRT overhead dominates at this size)",
                mean_a * 1e6,
                mean_n * 1e6
            );
        }
    } else {
        println!("(artifact benches skipped: run `make artifacts`)");
    }
}
