//! `cargo bench --bench bench_fig2` — regenerates Figure 2 (solver
//! comparison vs NFE on CIFAR-VE / ImageNet64-cosine / latent analogs).

use sadiff::exps::{fig2, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    for t in fig2::run(scale) {
        t.print();
    }
}
