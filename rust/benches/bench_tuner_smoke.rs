//! `cargo bench --bench bench_tuner_smoke` — deterministic smoke for the
//! autotuner: runs a tiny budget-aware search twice and across executor
//! widths, asserts the emitted registries are byte-identical (the tuner's
//! reproducibility contract), and writes a `BENCH_tuner_smoke.json`
//! artifact with timing + search stats for the perf trajectory (CI uploads
//! it per run and fails the job on nondeterministic output).
//!
//! Flags: `--quick` (fewer samples per evaluation), `--out <path>`
//! (default `BENCH_tuner_smoke.json`).

use sadiff::exec::Executor;
use sadiff::jsonlite::{to_string, Value};
use sadiff::tuner::{tune, TuneOptions};
use sadiff::util::timing::Stopwatch;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let out_path = args
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_tuner_smoke.json")
        .to_string();

    let opts = TuneOptions { n: if quick { 48 } else { 96 }, ..TuneOptions::quick() };
    let workloads = ["latent_analog".to_string()];
    let budgets = [5usize, 8];

    // Determinism gate 1: same options, two runs, sequential executor.
    let sw = Stopwatch::start();
    let seq_a = tune(&workloads, &budgets, &opts, &Executor::sequential()).expect("tune");
    let seq_secs = sw.secs();
    let seq_b = tune(&workloads, &budgets, &opts, &Executor::sequential()).expect("tune");
    let rerun_identical = seq_a.to_line() == seq_b.to_line();

    // Determinism gate 2: candidate fan-out across threads must not change
    // the emitted registry byte for byte.
    let par_exec = Executor::auto();
    let sw = Stopwatch::start();
    let par = tune(&workloads, &budgets, &opts, &par_exec).expect("tune");
    let par_secs = sw.secs();
    let threads_identical = par.to_line() == seq_a.to_line();

    let speedup = seq_secs / par_secs.max(1e-12);
    println!(
        "tuner smoke: {} presets, {} evals, {} threads: seq {:.0} ms, par {:.0} ms → {:.2}x \
         (rerun identical: {rerun_identical}, threads identical: {threads_identical})",
        seq_a.presets.len(),
        seq_a.search.evals,
        par_exec.threads(),
        seq_secs * 1e3,
        par_secs * 1e3,
        speedup
    );
    for p in &seq_a.presets {
        println!("  {} → {} (sim_fid {:.4})", p.name, p.cfg.solver.name(), p.sim_fid);
    }

    let report = Value::obj(vec![
        ("bench", Value::Str("tuner_smoke".into())),
        ("presets", Value::Num(seq_a.presets.len() as f64)),
        ("evals", Value::Num(seq_a.search.evals as f64)),
        ("threads", Value::Num(par_exec.threads() as f64)),
        ("seq_secs", Value::Num(seq_secs)),
        ("par_secs", Value::Num(par_secs)),
        ("speedup", Value::Num(speedup)),
        ("rerun_identical", Value::Bool(rerun_identical)),
        ("threads_identical", Value::Bool(threads_identical)),
    ]);
    if let Err(e) = std::fs::write(&out_path, format!("{}\n", to_string(&report))) {
        eprintln!("cannot write {out_path}: {e}");
        std::process::exit(1);
    }
    println!("wrote {out_path}");

    if !rerun_identical || !threads_identical {
        eprintln!("FAIL: tuner search output is nondeterministic");
        std::process::exit(1);
    }
}
