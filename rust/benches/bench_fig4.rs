//! `cargo bench --bench bench_fig4` — regenerates Figure 4 / Tables 8–9
//! (stochasticity vs inaccurate score estimation).

use sadiff::exps::{fig4, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    fig4::run(scale).print();
}
