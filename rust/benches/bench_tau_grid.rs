//! `cargo bench --bench bench_tau_grid` — regenerates the appendix
//! Tables 4–14 (τ × NFE FID grids per workload analog).

use sadiff::exps::{tau_grid, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    for t in tau_grid::run(scale) {
        t.print();
    }
}
