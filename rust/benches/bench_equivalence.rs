//! `cargo bench --bench bench_equivalence` — numerically verifies the §5.3
//! reductions (DDIM-η, DPM-Solver++(2M), UniPC-p as SA-Solver special
//! cases).

use sadiff::exps::equivalence;

fn main() {
    equivalence::run().print();
}
