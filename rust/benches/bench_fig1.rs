//! `cargo bench --bench bench_fig1` — regenerates Figure 1 (FID vs NFE × τ
//! on all four workload analogs).

use sadiff::exps::{fig1, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    for t in fig1::run(scale) {
        t.print();
    }
}
