//! `cargo bench --bench bench_ablations` — design-choice ablations beyond
//! the paper's tables: timestep selector, adaptive-SDE baseline [25],
//! coefficient-path determinism.

use sadiff::exps::{ablations, Scale};

fn main() {
    let scale = if std::env::args().any(|a| a == "--quick") {
        Scale::Quick
    } else {
        Scale::Full
    };
    for t in ablations::run(scale) {
        t.print();
    }
}
