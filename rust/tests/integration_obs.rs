//! End-to-end observability: a real served request leaves a valid Chrome
//! Trace Event dump containing queue-wait, step, model-eval and
//! checkpoint-write spans, and the `stats` snapshot carries per-stage
//! latency histograms for the same request.
//!
//! Everything lives in ONE `#[test]`: the span recorder is process-global
//! (started at server bind because `trace_path` is set), and the parallel
//! test harness must not run two tests that start/stop/dump it.

use sadiff::config::{SamplerConfig, ServerConfig};
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::SampleRequest;
use sadiff::jsonlite::{self, Value};

fn request(id: u64, n: usize, nfe: usize) -> SampleRequest {
    SampleRequest {
        id,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig { nfe, ..SamplerConfig::sa_default() },
        n,
        seed: id,
        return_samples: false,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    }
}

#[test]
fn served_request_produces_chrome_trace_and_stage_histograms() {
    let dir = std::env::temp_dir().join(format!("sadiff_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // The default dump lands in target/ so CI can upload it as a Perfetto
    // artifact (the file is intentionally left behind on success).
    std::fs::create_dir_all("target").unwrap();
    let trace_path = "target/serve_trace.json";
    let ck_path = dir.join("ck.json");
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        batch_deadline_ms: 3,
        workers: 1,
        queue_cap: 64,
        threads: 1,
        max_inflight: 2,
        checkpoint_path: Some(ck_path.to_str().unwrap().to_string()),
        checkpoint_every: 4,
        trace_path: Some(trace_path.to_string()),
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();

    let resp = client.request(&request(1, 4, 8)).unwrap();
    assert!(resp.ok, "{:?}", resp.error);

    // The group's retirement forces a checkpoint rewrite at the worker's
    // next boundary; wait for it so the dump below must contain the span.
    let mut checkpoints = 0.0;
    for _ in 0..200 {
        checkpoints = client.stats().unwrap().req_f64("checkpoints_written").unwrap();
        if checkpoints >= 1.0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(checkpoints >= 1.0, "no checkpoint written after a retired group");

    // Per-stage latency histograms cover the served request.
    let stats = client.stats().unwrap();
    let stages = stats.get("stages").expect("stats must carry a stages object");
    for key in ["queue_wait", "batch_merge", "solver_step", "model_eval", "checkpoint_write"] {
        let count = stages
            .get(key)
            .unwrap_or_else(|| panic!("stage {key} missing from stats"))
            .req_f64("count")
            .unwrap();
        assert!(count >= 1.0, "stage {key}: expected observations, got count {count}");
    }
    // One solver step per grid step at minimum, and a reply was written.
    assert!(stages.get("solver_step").unwrap().req_f64("count").unwrap() >= 8.0);
    assert!(stages.get("response_write").unwrap().req_f64("count").unwrap() >= 1.0);

    // Dump to the configured default path via the protocol verb.
    let reply = client.trace("dump", None).unwrap();
    assert!(reply.opt_bool("ok", false), "{reply:?}");
    assert_eq!(reply.req_str("path").unwrap(), trace_path);
    assert!(reply.req_f64("events").unwrap() >= 1.0);

    // The dump is valid Chrome Trace Event JSON with the promised spans.
    let text = std::fs::read_to_string(trace_path).unwrap();
    let v = jsonlite::parse(&text).unwrap();
    let events = v.get("traceEvents").and_then(Value::as_array).unwrap();
    let span_names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
        .map(|e| e.get("name").and_then(Value::as_str).unwrap())
        .collect();
    for name in ["queue_wait", "step", "batch_step", "model_eval", "checkpoint_write"] {
        assert!(
            span_names.iter().any(|n| *n == name),
            "span '{name}' missing from trace; got {span_names:?}"
        );
    }
    let labels: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Value::as_str) == Some("M"))
        .filter(|e| e.get("name").and_then(Value::as_str) == Some("thread_name"))
        .map(|e| e.get("args").and_then(|a| a.get("name")).and_then(Value::as_str).unwrap())
        .collect();
    assert!(
        labels.iter().any(|l| l.starts_with("sadiff-worker")),
        "worker lane missing from thread_name metadata: {labels:?}"
    );
    // The `sadiff trace` inspector accepts the same file.
    let lines = sadiff::obs::chrome::describe(&text).unwrap();
    assert!(lines[0].contains("span events"), "{}", lines[0]);

    // stop / start / dump-to-override round-trip.
    let r = client.trace("stop", None).unwrap();
    assert_eq!(r.opt_bool("tracing", true), false);
    let r = client.trace("start", None).unwrap();
    assert!(r.opt_bool("tracing", false));
    let alt = dir.join("alt_trace.json");
    let r = client.trace("dump", Some(alt.to_str().unwrap())).unwrap();
    assert!(r.opt_bool("ok", false), "{r:?}");
    assert!(alt.exists(), "dump with an explicit path must write that path");
    // Unknown action → error reply, never a dropped connection.
    let r = client.trace("flush", None).unwrap();
    assert!(r.get("error").is_some(), "{r:?}");
    assert_eq!(client.round_trip(r#"{"cmd":"ping"}"#).unwrap(), r#"{"ok":true}"#);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    // target/serve_trace.json is left on disk for the CI artifact upload.
}
