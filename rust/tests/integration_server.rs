//! Serving-path integration: real TCP round-trips against a spawned
//! server — protocol, batching, reproducibility, error handling, load
//! shedding and metrics.

use sadiff::config::{SamplerConfig, ServerConfig};
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::SampleRequest;
use sadiff::jsonlite;

type SpawnedServer = (sadiff::coordinator::server::ServerHandle, String);

fn spawn_server(max_batch: usize, workers: usize) -> SpawnedServer {
    spawn_server_threads(max_batch, workers, 1)
}

fn spawn_server_threads(max_batch: usize, workers: usize, threads: usize) -> SpawnedServer {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch,
        batch_deadline_ms: 3,
        workers,
        queue_cap: 64,
        threads,
        max_inflight: 4,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

fn request(n: usize, seed: u64, nfe: usize) -> SampleRequest {
    SampleRequest {
        id: seed,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig { nfe, ..SamplerConfig::sa_default() },
        n,
        seed,
        return_samples: true,
        want_metrics: true,
        preset: None,
        deadline_ms: None,
        priority: 0,
    }
}

#[test]
fn ping_stats_and_sample_roundtrip() {
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.round_trip(r#"{"cmd":"ping"}"#).unwrap(), r#"{"ok":true}"#);

    let resp = client.request(&request(4, 11, 8)).unwrap();
    assert!(resp.ok, "{:?}", resp.error);
    assert_eq!(resp.n, 4);
    assert_eq!(resp.nfe, 8);
    assert_eq!(resp.samples.as_ref().unwrap().len(), 4 * resp.dim);
    assert!(resp.sim_fid.is_some());

    let stats = client.stats().unwrap();
    assert_eq!(stats.req_f64("requests").unwrap(), 1.0);
    assert_eq!(stats.req_f64("responses_ok").unwrap(), 1.0);
    handle.shutdown();
}

#[test]
fn malformed_lines_get_error_responses() {
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    let bads =
        ["not json", r#"{"n": 0}"#, r#"{"cmd": "wat"}"#, r#"{"solver": {"solver": "bogus"}}"#];
    for bad in bads {
        let line = client.round_trip(bad).unwrap();
        let v = jsonlite::parse(&line).unwrap();
        assert_eq!(v.opt_bool("ok", true), false, "input {bad} -> {line}");
        assert!(v.get("error").is_some(), "input {bad} -> {line}");
    }
    // Server must still work afterwards.
    let resp = client.request(&request(2, 1, 6)).unwrap();
    assert!(resp.ok);
    handle.shutdown();
}

#[test]
fn batched_result_equals_solo_result() {
    // Fire compatible concurrent requests so the batcher merges them; each
    // must get exactly the samples it would get alone (engine invariant,
    // here verified across the full TCP + batcher + worker path).
    let (handle, addr) = spawn_server(8, 2);

    let solo = {
        let mut client = Client::connect(&addr).unwrap();
        client.request(&request(3, 777, 10)).unwrap()
    };

    let mut joins = Vec::new();
    for seed in [101u64, 777, 303, 404] {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            client.request(&request(3, seed, 10)).unwrap()
        }));
    }
    let responses: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let batched = responses.iter().find(|r| r.id == 777).unwrap();
    assert_eq!(
        batched.samples, solo.samples,
        "request 777 got different samples when batched with others"
    );

    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.req_f64("requests").unwrap() >= 5.0);
    handle.shutdown();
}

#[test]
fn batcher_group_through_parallel_executor_matches_sequential() {
    // Batcher + executor integration: pop a merged group off the batcher
    // and execute it on a multi-threaded executor — every request's
    // samples must equal the sequential single-threaded run of the same
    // group (the serving determinism invariant, below the TCP layer).
    use sadiff::coordinator::engine::{run_batch, run_batch_with};
    use sadiff::coordinator::Batcher;
    use sadiff::exec::Executor;
    use sadiff::workloads;

    let mut batcher = Batcher::new();
    for (seed, n) in [(10u64, 5usize), (11, 3), (12, 7)] {
        batcher.push(request(n, seed, 8));
    }
    let group = batcher.pop_group(8);
    assert_eq!(group.len(), 3, "compatible requests must merge");

    let wl = workloads::by_name(&group[0].workload).unwrap();
    let model = wl.model();
    let seq = run_batch(&*model, &wl, &group[0].cfg, &group);
    for threads in [2usize, 4] {
        let par = run_batch_with(&*model, &wl, &group[0].cfg, &group, &Executor::new(threads));
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.samples, b.samples, "threads={threads}, request id={}", a.id);
        }
    }
}

#[test]
fn lane_parallel_server_matches_sequential_server() {
    // Same request against a threads=1 server and a lane-parallel server:
    // identical samples over the full TCP + batcher + worker + executor
    // path.
    let (seq_handle, seq_addr) = spawn_server_threads(4, 1, 1);
    let (par_handle, par_addr) = spawn_server_threads(4, 2, 4);

    let seq = Client::connect(&seq_addr).unwrap().request(&request(6, 2024, 10)).unwrap();
    let par = Client::connect(&par_addr).unwrap().request(&request(6, 2024, 10)).unwrap();
    assert!(seq.ok && par.ok);
    assert_eq!(seq.samples, par.samples, "lane-parallel server changed samples");
    assert_eq!(seq.nfe, par.nfe);

    seq_handle.shutdown();
    par_handle.shutdown();
}

#[test]
fn request_admitted_mid_flight_is_bit_identical_to_solo() {
    // Continuous batching: with ONE worker, a request that arrives while a
    // long solve is in flight is admitted at a step boundary into the
    // worker's in-flight set (old behavior: it waited for the drain). Its
    // samples must equal an idle-server run bitwise — per-lane Philox
    // streams make results independent of co-scheduled work. Checked at
    // lane-executor widths 1 and 4.
    for threads in [1usize, 4] {
        let (handle, addr) = spawn_server_threads(8, 1, threads);

        // Reference run on the idle server.
        let solo = Client::connect(&addr).unwrap().request(&request(4, 4242, 12)).unwrap();
        assert!(solo.ok);

        // Long-running foreground solve (hundreds of steps over thousands
        // of lanes — wide enough that it is still mid-flight when the late
        // request arrives, on any machine).
        let long_addr = addr.clone();
        let long = std::thread::spawn(move || {
            let mut client = Client::connect(&long_addr).unwrap();
            client.request(&request(2048, 7, 500)).unwrap()
        });
        // Give it time to be admitted and start stepping.
        std::thread::sleep(std::time::Duration::from_millis(60));

        let late = Client::connect(&addr).unwrap().request(&request(4, 4242, 12)).unwrap();
        assert!(late.ok, "{:?}", late.error);
        assert_eq!(
            late.samples, solo.samples,
            "threads={threads}: mid-flight admission changed the samples"
        );
        let long_resp = long.join().unwrap();
        assert!(long_resp.ok, "{:?}", long_resp.error);

        let mut client = Client::connect(&addr).unwrap();
        let stats = client.stats().unwrap();
        assert!(stats.req_f64("steps").unwrap() >= 300.0, "scheduler reported too few steps");
        assert!(stats.req_f64("step_lanes").unwrap() >= stats.req_f64("steps").unwrap());
        assert_eq!(stats.req_f64("inflight_groups").unwrap(), 0.0, "drained server");
        assert_eq!(stats.req_f64("inflight_lanes").unwrap(), 0.0);
        handle.shutdown();
    }
}

#[test]
fn cancel_frees_lanes_without_corrupting_cobatched_requests() {
    // A heavy request and a small compatible request merge into one lane
    // group (generous batching window). Cancelling the heavy one mid-run
    // must (a) answer its connection with {"error":"cancelled"}, (b) leave
    // the co-batched survivor bit-identical to a solo run, and (c) free
    // the lanes so the server keeps serving.
    use sadiff::coordinator::engine::run_batch;
    use sadiff::workloads;

    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        batch_deadline_ms: 150,
        workers: 1,
        queue_cap: 64,
        // The 2-lane survivor queues behind 4000 lanes inside the batching
        // window; keep lane-aware shedding out of this test's way.
        queue_lane_cap: 8192,
        threads: 1,
        max_inflight: 2,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    // Solo reference for the survivor, computed engine-side (the server's
    // batch path is bit-identical to this by the engine's contract).
    let survivor_req = request(2, 606, 5000);
    let wl = workloads::by_name(&survivor_req.workload).unwrap();
    let model = wl.model();
    let solo = run_batch(&*model, &wl, &survivor_req.cfg, &[survivor_req.clone()]);

    // Heavy victim (id 900) and the survivor (id 606), sent within the
    // batching window so they merge.
    let heavy_addr = addr.clone();
    let heavy = std::thread::spawn(move || {
        let mut client = Client::connect(&heavy_addr).unwrap();
        client.request(&request(4000, 900, 5000)).unwrap()
    });
    let surv_addr = addr.clone();
    let surv = std::thread::spawn(move || {
        let mut client = Client::connect(&surv_addr).unwrap();
        client.request(&request(2, 606, 5000)).unwrap()
    });

    // Let the pair merge and start stepping, then cancel the heavy one.
    std::thread::sleep(std::time::Duration::from_millis(300));
    let mut canceller = Client::connect(&addr).unwrap();
    let mut cancelled_somewhere = false;
    for _ in 0..200 {
        let v = canceller.cancel(900).unwrap();
        assert!(v.opt_bool("ok", false));
        let hit = v.req_f64("cancelled_queued").unwrap() + v.req_f64("cancel_pending").unwrap();
        if hit >= 1.0 {
            cancelled_somewhere = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(cancelled_somewhere, "cancel never found request 900 (finished too fast?)");

    let heavy_resp = heavy.join().unwrap();
    assert!(!heavy_resp.ok, "heavy request was not cancelled");
    assert_eq!(heavy_resp.error.as_deref(), Some("cancelled"));

    let surv_resp = surv.join().unwrap();
    assert!(surv_resp.ok, "{:?}", surv_resp.error);
    assert_eq!(
        surv_resp.samples,
        solo[0].samples.clone(),
        "cancel corrupted the co-batched survivor"
    );

    // Lanes are freed: the server still serves, and the gauges drain.
    let mut client = Client::connect(&addr).unwrap();
    let after = client.request(&request(2, 1, 6)).unwrap();
    assert!(after.ok);
    let stats = client.stats().unwrap();
    assert!(stats.req_f64("cancelled").unwrap() >= 1.0);
    assert_eq!(stats.req_f64("inflight_lanes").unwrap(), 0.0);
    handle.shutdown();
}

#[test]
fn cancelling_every_queued_request_drops_the_group_entirely() {
    // A generous batching window keeps the pair queued; cancelling both
    // must empty their would-be group before admission — the scheduler must
    // never admit a zero-lane group (steps stay 0), both connections get
    // {"error":"cancelled"}, and the server keeps serving afterwards.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        batch_deadline_ms: 1000,
        workers: 1,
        queue_cap: 64,
        threads: 1,
        max_inflight: 2,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    let mut waiters = Vec::new();
    for seed in [701u64, 702] {
        let addr = addr.clone();
        waiters.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            client.request(&request(2, seed, 2000)).unwrap()
        }));
    }
    // Let both enqueue, then cancel them inside the batching window.
    std::thread::sleep(std::time::Duration::from_millis(100));
    let mut canceller = Client::connect(&addr).unwrap();
    let mut removed = 0.0;
    for seed in [701u64, 702] {
        let v = canceller.cancel(seed).unwrap();
        assert!(v.opt_bool("ok", false));
        removed += v.req_f64("cancelled_queued").unwrap();
    }
    assert_eq!(removed, 2.0, "both requests should be cancelled while queued");
    for w in waiters {
        let resp = w.join().unwrap();
        assert!(!resp.ok);
        assert_eq!(resp.error.as_deref(), Some("cancelled"));
    }
    // The emptied group was dropped, not scheduled with zero lanes.
    let resp = canceller.request(&request(2, 9, 6)).unwrap();
    assert!(resp.ok, "{:?}", resp.error);
    let stats = canceller.stats().unwrap();
    assert_eq!(stats.req_f64("cancelled").unwrap(), 2.0);
    assert_eq!(stats.req_f64("inflight_groups").unwrap(), 0.0);
    handle.shutdown();
}

#[test]
fn double_cancel_of_the_same_id_is_a_clean_zero_count() {
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    let resp = client.request(&request(2, 77, 6)).unwrap();
    assert!(resp.ok);
    // The request already completed: both cancels find nothing, both get
    // clean ok replies with zero counts (no error, no crash, no hang).
    for _ in 0..2 {
        let v = client.cancel(77).unwrap();
        assert!(v.opt_bool("ok", false));
        assert_eq!(v.req_f64("cancelled_queued").unwrap(), 0.0);
        assert_eq!(v.req_f64("cancel_pending").unwrap(), 0.0);
    }
    handle.shutdown();
}

#[test]
fn cancel_with_unknown_id_or_missing_id_is_clean() {
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    let v = client.cancel(5555).unwrap();
    assert!(v.opt_bool("ok", false));
    assert_eq!(v.req_f64("cancelled_queued").unwrap(), 0.0);
    assert_eq!(v.req_f64("cancel_pending").unwrap(), 0.0);
    // Missing id → protocol error, not a crash.
    let line = client.round_trip(r#"{"cmd":"cancel"}"#).unwrap();
    let v = jsonlite::parse(&line).unwrap();
    assert!(!v.opt_bool("ok", true));
    assert!(v.req_str("error").unwrap().contains("id"));
    handle.shutdown();
}

#[test]
fn unknown_workload_is_an_error_response() {
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    let mut req = request(2, 5, 6);
    req.workload = "not_a_workload".into();
    let resp = client.request(&req).unwrap();
    assert!(!resp.ok);
    assert!(resp.error.as_ref().unwrap().contains("unknown workload"));
    handle.shutdown();
}

#[test]
fn concurrent_mixed_configs_all_succeed() {
    let (handle, addr) = spawn_server(4, 2);
    let mut joins = Vec::new();
    for i in 0..10u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            // Two distinct config groups → exercises group separation.
            let nfe = if i % 2 == 0 { 6 } else { 12 };
            client.request(&request(2, i, nfe)).unwrap()
        }));
    }
    for j in joins {
        let r = j.join().unwrap();
        assert!(r.ok, "{:?}", r.error);
    }
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.req_f64("responses_ok").unwrap(), 10.0);
    // Batching must have merged at least some of the 10 requests.
    assert!(stats.req_f64("batches").unwrap() <= 9.0);
    handle.shutdown();
}

#[test]
fn load_shedding_under_queue_cap() {
    // queue_cap 2 with a single slow worker: flood and expect some sheds
    // to be reported as clean errors, not hangs.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        batch_deadline_ms: 1,
        workers: 1,
        queue_cap: 2,
        threads: 1,
        max_inflight: 1,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();
    let mut joins = Vec::new();
    for i in 0..12u64 {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            // Heavier request so the queue actually builds up.
            client.request(&request(64, i, 40)).unwrap()
        }));
    }
    let responses: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let ok = responses.iter().filter(|r| r.ok).count();
    let shed = responses
        .iter()
        .filter(|r| !r.ok && r.error.as_deref().unwrap_or("").contains("overloaded"))
        .count();
    assert_eq!(ok + shed, 12, "every request must get a definite answer");
    assert!(ok >= 1, "at least some requests must succeed");
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.req_f64("shed").unwrap() as usize, shed);
    handle.shutdown();
}

#[test]
fn lane_aware_shedding_sheds_on_queued_lanes_not_just_request_count() {
    // Regression (lane-blind shedding): queue_cap is generous (64
    // requests) but the queued-lane cap is 100, so a second wide request
    // must be shed by lane pressure even though the request-count check
    // alone would admit it. Pre-fix, only `batcher.len() >= queue_cap`
    // shed, so a handful of wide requests could swamp every step budget.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        batch_deadline_ms: 1,
        workers: 1,
        queue_cap: 64,
        queue_lane_cap: 100,
        threads: 1,
        max_inflight: 1,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    // Blocker: wider than the lane cap, admitted anyway (an empty queue
    // always admits), and holds the single in-flight slot for the test.
    let blocker_addr = addr.clone();
    let blocker = std::thread::spawn(move || {
        let mut client = Client::connect(&blocker_addr).unwrap();
        client.request(&request(1024, 900, 10_000)).unwrap()
    });
    std::thread::sleep(std::time::Duration::from_millis(200));

    // 64 queued lanes: under the cap, accepted and queued (the worker's
    // in-flight slot is taken).
    let filler_addr = addr.clone();
    let filler = std::thread::spawn(move || {
        let mut client = Client::connect(&filler_addr).unwrap();
        client.request(&request(64, 901, 10_000)).unwrap()
    });
    std::thread::sleep(std::time::Duration::from_millis(100));

    // 64 more lanes would make 128 > 100 queued lanes, with only ONE
    // queued request (far under queue_cap): must shed — typed, with a
    // backoff hint.
    let mut client = Client::connect(&addr).unwrap();
    let resp = client.request(&request(64, 902, 8)).unwrap();
    assert!(!resp.ok, "lane-blind admission: wide request was accepted");
    assert_eq!(resp.kind.as_deref(), Some("shed"));
    assert!(resp.retry_after_ms.is_some(), "shed reply must carry retry_after_ms");
    assert!(resp.error.as_deref().unwrap_or("").contains("overloaded"), "{:?}", resp.error);
    let stats = client.stats().unwrap();
    assert!(stats.req_f64("shed").unwrap() >= 1.0);
    assert!(stats.req_f64("queued_samples").unwrap() <= 100.0);

    // Unblock: cancel the blocker and the queued filler, then drain.
    for id in [900u64, 901] {
        let mut hit = false;
        for _ in 0..200 {
            let v = client.cancel(id).unwrap();
            if v.req_f64("cancelled_queued").unwrap() + v.req_f64("cancel_pending").unwrap()
                >= 1.0
            {
                hit = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(hit, "could not cancel request {id}");
    }
    assert!(!blocker.join().unwrap().ok);
    assert!(!filler.join().unwrap().ok);
    handle.shutdown();
}

#[test]
fn client_timeout_cancels_the_ticket_and_frees_lanes() {
    // Regression (orphaned-reply leak): a connection that gives up
    // waiting must (a) get a typed `timeout` reply after
    // `reply_timeout_ms`, (b) have its ticket cancelled through the
    // normal cancel path so the in-flight lanes drain, and (c) be counted
    // in `timeouts`, `responses_err` and the latency histogram. Pre-fix,
    // the reply sender leaked in `replies` and the abandoned solve kept
    // burning NFEs to the very end.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        batch_deadline_ms: 1,
        workers: 1,
        queue_cap: 64,
        reply_timeout_ms: 300,
        threads: 1,
        max_inflight: 2,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    let mut client = Client::connect(&addr).unwrap();
    let t0 = std::time::Instant::now();
    // 40M lane-steps: far beyond what 300 ms can finish (the cancel tests
    // rely on 20M still being mid-flight after the same wait).
    let resp = client.request(&request(4000, 31, 10_000)).unwrap();
    let waited = t0.elapsed();
    assert!(!resp.ok);
    assert_eq!(resp.kind.as_deref(), Some("timeout"));
    assert!(resp.error.as_deref().unwrap_or("").contains("timeout"), "{:?}", resp.error);
    assert!(
        waited >= std::time::Duration::from_millis(280),
        "replied before the timeout: {waited:?}"
    );

    // The cancel path frees the lanes at the owning worker's next step
    // boundary; poll the gauges until they drain.
    let mut stats = client.stats().unwrap();
    let mut drained = false;
    for _ in 0..1000 {
        if stats.req_f64("inflight_lanes").unwrap() == 0.0
            && stats.req_f64("inflight_groups").unwrap() == 0.0
        {
            drained = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        stats = client.stats().unwrap();
    }
    assert!(drained, "timed-out request still holds lanes: {}", jsonlite::to_string(&stats));
    // Stats account for the timeout (undercount regression): the counter,
    // the error tally and the latency histogram all see it.
    assert_eq!(stats.req_f64("timeouts").unwrap(), 1.0);
    assert!(stats.req_f64("responses_err").unwrap() >= 1.0);
    assert!(stats.req_f64("cancelled").unwrap() >= 1.0);
    assert!(stats.req_f64("latency_p50_ms").unwrap() > 0.0, "timeout latency not observed");

    // The same connection keeps working afterwards.
    let after = client.request(&request(2, 32, 6)).unwrap();
    assert!(after.ok, "{:?}", after.error);
    handle.shutdown();
}

#[test]
fn invalid_utf8_line_gets_error_reply_not_a_dropped_connection() {
    use std::io::{BufRead, BufReader, Write};
    let (handle, addr) = spawn_server(4, 1);
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.write_all(b"\xff\xfe{not utf8}\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = jsonlite::parse(line.trim_end()).unwrap();
    assert!(!v.opt_bool("ok", true));
    assert!(v.req_str("error").unwrap().contains("utf-8"), "{line}");
    // Connection must still be usable.
    stream.write_all(b"{\"cmd\":\"ping\"}\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), r#"{"ok":true}"#);
    handle.shutdown();
}

#[test]
fn presets_cmd_without_registry_reports_error() {
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    let v = jsonlite::parse(&client.round_trip(r#"{"cmd":"presets"}"#).unwrap()).unwrap();
    assert!(!v.opt_bool("ok", true));
    assert!(v.req_str("error").unwrap().contains("no preset registry"));
    // A request asking for a preset is an error, not a hang or a crash.
    let mut req = request(2, 3, 6);
    req.preset = Some("auto".into());
    let resp = client.request(&req).unwrap();
    assert!(!resp.ok);
    assert!(resp.error.as_ref().unwrap().contains("no registry loaded"));
    handle.shutdown();
}

#[test]
fn stats_include_queue_depth() {
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    let _ = client.request(&request(2, 1, 6)).unwrap();
    let stats = client.stats().unwrap();
    // Drained by now, but the gauge must exist and be a number.
    assert!(stats.req_f64("queued_samples").unwrap() >= 0.0);
    handle.shutdown();
}

#[test]
fn shutdown_after_protocol_shutdown_does_not_hang() {
    // A client-initiated shutdown exits the accept thread; the handle's
    // shutdown() afterwards must join cleanly (the poke-connect fails, but
    // the join still runs) instead of hanging or panicking.
    let (handle, addr) = spawn_server(4, 1);
    let mut client = Client::connect(&addr).unwrap();
    let line = client.round_trip(r#"{"cmd":"shutdown"}"#).unwrap();
    assert!(line.contains("shutting_down"));
    // Give the accept thread a moment to observe the flag and exit.
    std::thread::sleep(std::time::Duration::from_millis(50));
    handle.shutdown(); // must return promptly
}

#[test]
fn config_file_drives_server() {
    // ServerConfig::from_json + load_json_file round-trip through a file.
    let dir = std::env::temp_dir().join(format!("sadiff_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("server.json");
    std::fs::write(&path, r#"{"addr": "127.0.0.1:0", "max_batch": 3, "workers": 1}"#).unwrap();
    let v = sadiff::config::load_json_file(path.to_str().unwrap()).unwrap();
    let cfg = ServerConfig::from_json(&v).unwrap();
    assert_eq!(cfg.max_batch, 3);
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();
    assert!(client.round_trip(r#"{"cmd":"ping"}"#).unwrap().contains("true"));
    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
