//! The stepper equivalence contract, end to end: for every `SolverKind`,
//! the incremental `Stepper` path must reproduce the monolithic seed-era
//! `solve()` loops (`solvers::run_reference`) bitwise — stepping one step
//! at a time, under arbitrary splits of the step sequence across driving
//! loops, interleaved with other in-flight runs, at any executor width,
//! and across mid-run lane cancellation.

use sadiff::config::{SamplerConfig, SolverKind};
use sadiff::coordinator::engine::{run_batch, BatchRun};
use sadiff::coordinator::SampleRequest;
use sadiff::exec::Executor;
use sadiff::gmm::Gmm;
use sadiff::models::{GmmAnalytic, ModelEval};
use sadiff::rng::normal::PhiloxNormal;
use sadiff::schedule::{timesteps, NoiseSchedule};
use sadiff::solvers::stepper::{make_stepper, Stepper};
use sadiff::solvers::{prior_sample, run, run_parallel, run_reference, Grid};
use sadiff::workloads;
use std::sync::Arc;

fn tiny_model() -> GmmAnalytic {
    GmmAnalytic::new(Gmm::structured(3, 3, 1.5, 11))
}

#[test]
fn stepper_matches_monolithic_for_every_solver_at_any_split() {
    // Drive each solver's stepper (a) continuously and (b) in two separate
    // loops split at every interesting boundary. Both the mid-run state at
    // the split and the final output must equal the continuous run, and
    // the continuous run must equal the monolithic reference — bitwise.
    let model = tiny_model();
    let sch = NoiseSchedule::vp_linear();
    let n = 6;
    for kind in SolverKind::all() {
        let mut cfg = SamplerConfig::for_solver(*kind);
        cfg.nfe = 14;
        let want = run_reference(&model, &sch, &cfg, n, 77);

        let m = cfg.steps_for_nfe();
        let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
        let mut noise = PhiloxNormal::new(77);
        let mut x = prior_sample(&grid, model.dim(), n, &mut noise);
        let mut st: Box<dyn Stepper> = make_stepper(&cfg, &sch);
        st.init(&model, &grid, &mut x, n, &mut noise);
        let mut traj = Vec::with_capacity(m);
        for i in 0..m {
            st.step(&model, &grid, i, &mut x, n, &mut noise);
            traj.push(x.clone());
        }
        st.finish(&mut x);
        assert_eq!(x, want.samples, "{kind:?}: continuous stepper != reference");

        for k in [0usize, 1, m / 2, m - 1] {
            let mut noise = PhiloxNormal::new(77);
            let mut xb = prior_sample(&grid, model.dim(), n, &mut noise);
            let mut stb: Box<dyn Stepper> = make_stepper(&cfg, &sch);
            stb.init(&model, &grid, &mut xb, n, &mut noise);
            for i in 0..k {
                stb.step(&model, &grid, i, &mut xb, n, &mut noise);
            }
            if k > 0 {
                assert_eq!(xb, traj[k - 1], "{kind:?}: mid-run state at split {k}");
            }
            for i in k..m {
                stb.step(&model, &grid, i, &mut xb, n, &mut noise);
            }
            stb.finish(&mut xb);
            assert_eq!(xb, want.samples, "{kind:?}: split at {k} changed the output");
        }
    }
}

#[test]
fn stepper_matches_monolithic_for_non_default_configs() {
    // The configs tuned presets actually serve are not the per-solver
    // defaults. Drive the config-dependent stepper branches — SA's
    // interval-τ path (ξ injected on some steps only, exercising the
    // xi_dirty re-zeroing), noise prediction, predictor-only SA, DDIM with
    // η > 0, UniPC with the corrector disabled, the EDM churn band, the
    // ρ-shaped grid — against the monolithic reference, continuously and
    // split at m/2.
    use sadiff::config::{Prediction, SamplerConfig, SolverKind, TauKind};
    use sadiff::schedule::StepSelector;

    let model = tiny_model();
    let sch = NoiseSchedule::vp_linear();
    let mut cfgs: Vec<(&str, SamplerConfig)> = Vec::new();

    let mut sa_interval = SamplerConfig::sa_default();
    sa_interval.nfe = 16;
    sa_interval.tau_kind = TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 };
    sa_interval.predictor_steps = 2;
    sa_interval.corrector_steps = 2;
    cfgs.push(("sa interval-tau", sa_interval));

    let mut sa_noise = SamplerConfig::sa_default();
    sa_noise.nfe = 14;
    sa_noise.prediction = Prediction::Noise;
    sa_noise.tau = 0.4;
    sa_noise.corrector_steps = 1;
    cfgs.push(("sa noise-prediction", sa_noise));

    let mut sa_pred_only = SamplerConfig::sa_default();
    sa_pred_only.nfe = 12;
    sa_pred_only.tau = 0.0;
    sa_pred_only.corrector_steps = 0;
    cfgs.push(("sa predictor-only ODE", sa_pred_only));

    let mut ddim_eta = SamplerConfig::for_solver(SolverKind::Ddim);
    ddim_eta.nfe = 12;
    ddim_eta.eta = 1.0;
    cfgs.push(("ddim eta=1", ddim_eta));

    let mut unipc_p_only = SamplerConfig::for_solver(SolverKind::UniPc);
    unipc_p_only.nfe = 12;
    unipc_p_only.predictor_steps = 2;
    unipc_p_only.corrector_steps = 0;
    cfgs.push(("unipc corrector-off", unipc_p_only));

    let mut edm_churn = SamplerConfig::for_solver(SolverKind::EdmSde);
    edm_churn.nfe = 13;
    edm_churn.churn = 10.0;
    edm_churn.s_tmin = 0.1;
    edm_churn.s_tmax = 10.0;
    edm_churn.selector = StepSelector::EdmRho { rho: 7.0 };
    cfgs.push(("edm_sde churn band", edm_churn));

    let mut heun_rho = SamplerConfig::for_solver(SolverKind::Heun);
    heun_rho.nfe = 13;
    heun_rho.selector = StepSelector::EdmRho { rho: 5.0 };
    cfgs.push(("heun rho grid", heun_rho));

    let mut em = SamplerConfig::for_solver(SolverKind::EulerMaruyama);
    em.nfe = 15;
    em.tau = 0.3;
    cfgs.push(("euler_maruyama tau=0.3", em));

    for (name, cfg) in &cfgs {
        let n = 5;
        let want = run_reference(&model, &sch, cfg, n, 99);
        let got = run(&model, &sch, cfg, n, 99);
        assert_eq!(got.samples, want.samples, "{name}: stepper != monolithic");
        assert_eq!(got.nfe, want.nfe, "{name}: NFE diverged");

        // Split drive at m/2 (pauses must not disturb carried state —
        // notably SA's xi_dirty flag on interval-τ schedules).
        let m = cfg.steps_for_nfe();
        let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
        let mut noise = PhiloxNormal::new(99);
        let mut x = prior_sample(&grid, model.dim(), n, &mut noise);
        let mut st: Box<dyn Stepper> = make_stepper(cfg, &sch);
        st.init(&model, &grid, &mut x, n, &mut noise);
        for i in 0..m / 2 {
            st.step(&model, &grid, i, &mut x, n, &mut noise);
        }
        for i in m / 2..m {
            st.step(&model, &grid, i, &mut x, n, &mut noise);
        }
        st.finish(&mut x);
        assert_eq!(x, want.samples, "{name}: split drive diverged");
    }
}

#[test]
fn stepper_parallel_matches_monolithic_reference_any_thread_count() {
    // The production entry points (driver + lane-chunked executor) against
    // the seed-era monolithic path, across thread counts and awkward
    // chunk shapes.
    let model = tiny_model();
    let sch = NoiseSchedule::vp_linear();
    for kind in SolverKind::all() {
        let mut cfg = SamplerConfig::for_solver(*kind);
        cfg.nfe = 10;
        for (n, threads) in [(13usize, 4usize), (5, 1), (3, 8)] {
            let want = run_reference(&model, &sch, &cfg, n, 7);
            let got = run_parallel(&model, &sch, &cfg, n, 7, &Executor::new(threads));
            assert_eq!(
                got.samples, want.samples,
                "{kind:?}: stepper (n={n}, threads={threads}) != monolithic reference"
            );
            assert_eq!(got.nfe, want.nfe, "{kind:?}: NFE accounting diverged");
        }
    }
}

#[test]
fn interleaved_stepping_of_independent_runs_matches_solo() {
    // The step-synchronous scheduler's core assumption: advancing two
    // in-flight runs alternately (different grids, different step counts)
    // is invisible to each — both equal their solo runs bitwise.
    let model = tiny_model();
    let sch = NoiseSchedule::vp_linear();
    for kind in [SolverKind::Sa, SolverKind::UniPc, SolverKind::DpmSolverPp2m, SolverKind::EdmSde]
    {
        let mut cfg_a = SamplerConfig::for_solver(kind);
        cfg_a.nfe = 12;
        let mut cfg_b = SamplerConfig::for_solver(kind);
        cfg_b.nfe = 9;
        let solo_a = run(&model, &sch, &cfg_a, 4, 5);
        let solo_b = run(&model, &sch, &cfg_b, 3, 6);

        let (ma, mb) = (cfg_a.steps_for_nfe(), cfg_b.steps_for_nfe());
        let grid_a = Grid::new(&sch, timesteps(&sch, cfg_a.selector, ma));
        let grid_b = Grid::new(&sch, timesteps(&sch, cfg_b.selector, mb));
        let mut noise_a = PhiloxNormal::new(5);
        let mut noise_b = PhiloxNormal::new(6);
        let mut xa = prior_sample(&grid_a, model.dim(), 4, &mut noise_a);
        let mut xb = prior_sample(&grid_b, model.dim(), 3, &mut noise_b);
        let mut st_a: Box<dyn Stepper> = make_stepper(&cfg_a, &sch);
        let mut st_b: Box<dyn Stepper> = make_stepper(&cfg_b, &sch);
        st_a.init(&model, &grid_a, &mut xa, 4, &mut noise_a);
        st_b.init(&model, &grid_b, &mut xb, 3, &mut noise_b);
        let (mut ia, mut ib) = (0usize, 0usize);
        while ia < ma || ib < mb {
            if ia < ma {
                st_a.step(&model, &grid_a, ia, &mut xa, 4, &mut noise_a);
                ia += 1;
            }
            if ib < mb {
                st_b.step(&model, &grid_b, ib, &mut xb, 3, &mut noise_b);
                ib += 1;
            }
        }
        st_a.finish(&mut xa);
        st_b.finish(&mut xb);
        assert_eq!(xa, solo_a.samples, "{kind:?}: interleaving changed run A");
        assert_eq!(xb, solo_b.samples, "{kind:?}: interleaving changed run B");
    }
}

#[test]
fn batch_run_cancel_survivors_bit_identical_for_every_solver() {
    // Mid-run cancellation exercises every stepper's `retain_lanes` (the
    // history-buffer solvers are the interesting ones): cancel the middle
    // request of a merged batch halfway through and check both survivors
    // against their solo runs, at two executor widths.
    let wl = workloads::latent_analog();
    let req = |id: u64, n: usize, seed: u64, cfg: &SamplerConfig| SampleRequest {
        id,
        workload: wl.name.into(),
        model: "gmm".into(),
        cfg: cfg.clone(),
        n,
        seed,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    };
    for kind in SolverKind::all() {
        let mut cfg = SamplerConfig::for_solver(*kind);
        cfg.nfe = 10;
        let reqs = [req(0, 3, 41, &cfg), req(1, 4, 42, &cfg), req(2, 2, 43, &cfg)];
        let model = wl.model();
        let solo_a = run_batch(&*model, &wl, &cfg, &reqs[0..1]);
        let solo_c = run_batch(&*model, &wl, &cfg, &reqs[2..3]);
        for threads in [1usize, 3] {
            let exec = Executor::new(threads);
            let model: Arc<dyn ModelEval> = Arc::from(wl.model());
            let mut br = BatchRun::new(model, &wl, &cfg, reqs.to_vec(), &exec);
            let half = br.progress().1 / 2;
            for _ in 0..half {
                br.step(&exec);
            }
            let resp = br.cancel(1).expect("middle request is in flight");
            assert_eq!(resp.error.as_deref(), Some("cancelled"), "{kind:?}");
            while !br.step(&exec) {}
            let got = br.finish();
            assert_eq!(got.len(), 2, "{kind:?}");
            assert_eq!(
                got[0].samples, solo_a[0].samples,
                "{kind:?} threads={threads}: survivor A corrupted by cancel"
            );
            assert_eq!(
                got[1].samples, solo_c[0].samples,
                "{kind:?} threads={threads}: survivor C corrupted by cancel"
            );
        }
    }
}
