//! Persistent exec-pool contracts, end to end: a long-lived pool shared
//! by interleaved `BatchRun`s stays bit-identical to sequential across
//! hundreds of steps; a panicking chunk task fails the dispatching caller
//! without deadlocking or wedging the pool; `Drop` joins every worker (no
//! thread leak across create/drop cycles); and concurrent dispatches from
//! independent threads serialize correctly.
//!
//! Everything lives in ONE `#[test]`: the worker-liveness assertions read
//! the process-wide `live_pool_workers` counter, which a concurrently
//! running pool test would pollute (same policy as `integration_alloc`).

use sadiff::config::SamplerConfig;
use sadiff::coordinator::engine::{run_batch, BatchRun};
use sadiff::coordinator::SampleRequest;
use sadiff::exec::{live_pool_workers, Executor};
use sadiff::models::ModelEval;
use sadiff::workloads;
use std::sync::Arc;

fn req(id: u64, n: usize, seed: u64, nfe: usize) -> SampleRequest {
    SampleRequest {
        id,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig { nfe, ..SamplerConfig::sa_default() },
        n,
        seed,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    }
}

/// Two `BatchRun`s stepped alternately through ONE shared pool, hundreds
/// of scheduler steps total, must finish bit-identical to their sequential
/// `run_batch` references — the serving scheduler's shape (a server worker
/// interleaves its in-flight groups on the one server executor).
fn interleaved_batch_runs_stay_bit_identical() {
    let wl = workloads::latent_analog();
    let cfg_a = SamplerConfig { nfe: 96, ..SamplerConfig::sa_default() };
    let cfg_b = SamplerConfig { nfe: 120, ..SamplerConfig::sa_default() };
    let reqs_a = [req(0, 5, 999, 96), req(1, 3, 111, 96)];
    let reqs_b = [req(2, 2, 222, 120), req(3, 4, 333, 120)];
    let model = wl.model();
    let want_a = run_batch(&*model, &wl, &cfg_a, &reqs_a);
    let want_b = run_batch(&*model, &wl, &cfg_b, &reqs_b);

    let exec = Executor::new(3);
    let model_a: Arc<dyn ModelEval> = Arc::from(wl.model());
    let model_b: Arc<dyn ModelEval> = Arc::from(wl.model());
    let mut run_a = BatchRun::new(model_a, &wl, &cfg_a, reqs_a.to_vec(), &exec);
    let mut run_b = BatchRun::new(model_b, &wl, &cfg_b, reqs_b.to_vec(), &exec);
    let mut steps = 0usize;
    loop {
        let done_a = run_a.step(&exec);
        let done_b = run_b.step(&exec);
        steps += 1;
        assert!(steps < 10_000, "runs failed to finish");
        if done_a && done_b {
            break;
        }
    }
    assert!(steps >= 100, "expected hundreds of interleaved steps, got {steps}");
    for (want, got) in [(want_a, run_a.finish()), (want_b, run_b.finish())] {
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.samples, b.samples, "id={}: pooled != sequential", a.id);
            assert_eq!(a.nfe, b.nfe, "id={}", a.id);
        }
    }
}

/// A panicking chunk task must panic the dispatching caller (not hang it
/// on the completion latch), and the pool must keep serving correct
/// dispatches afterwards — the poisoned-dispatch error path.
fn pool_survives_chunk_panics() {
    let exec = Executor::new(4);
    let expect: Vec<u64> = (0..64u64).map(|v| v * 3).collect();

    // Worker-part panic (item 2 lands on a pool worker at 4 parts).
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut items = [0u64; 4];
        exec.for_each_mut(&mut items, |i, _| {
            if i == 2 {
                panic!("injected worker-part failure");
            }
        });
    }));
    assert!(r.is_err(), "a panicking worker part must fail the dispatch");

    // Caller-part panic (part 0 runs inline on the dispatching thread).
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut items = [0u64; 4];
        exec.for_each_mut(&mut items, |i, _| {
            if i == 0 {
                panic!("injected caller-part failure");
            }
        });
    }));
    assert!(r.is_err(), "a panicking caller part must fail the dispatch");

    // Every part panics at once: the latch must still open.
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut items = [0u64; 4];
        exec.for_each_mut(&mut items, |_, _| panic!("injected all-part failure"));
    }));
    assert!(r.is_err());

    // The pool is still fully usable and correct after all of the above.
    for _ in 0..50 {
        let got: Vec<u64> =
            exec.run_chunks(64, |r| r.map(|i| i as u64 * 3).collect::<Vec<_>>()).concat();
        assert_eq!(got, expect, "pool must keep working after caught panics");
    }
}

/// `Executor::new` spawns `threads - 1` workers; dropping the last clone
/// joins them all. Repeated create/dispatch/drop cycles must return the
/// process-wide live-worker count to its baseline every time.
fn drop_joins_all_workers() {
    let baseline = live_pool_workers();
    for cycle in 0..25usize {
        let exec = Executor::new(5);
        assert_eq!(live_pool_workers(), baseline + 4, "cycle {cycle}: 4 workers live");
        let sums = exec.run_chunks(40, |r| r.sum::<usize>());
        assert_eq!(sums.iter().sum::<usize>(), (0..40).sum::<usize>());
        let clone = exec.clone();
        drop(exec);
        // A live clone keeps the shared pool alive...
        assert_eq!(live_pool_workers(), baseline + 4, "cycle {cycle}: clone holds the pool");
        drop(clone);
        // ...and dropping the last handle joins every worker before
        // returning, so the count is back to baseline immediately.
        assert_eq!(live_pool_workers(), baseline, "cycle {cycle}: workers leaked");
    }
    // Sequential executors never spawn a pool at all.
    let exec = Executor::sequential();
    assert_eq!(live_pool_workers(), baseline);
    drop(exec);
}

/// Concurrent dispatches from independent caller threads (the server's
/// `workers > 1` shape — several engine workers sharing one pool) must
/// serialize without deadlock and produce sequential results. A generous
/// stress: 4 callers × 100 dispatches each.
fn concurrent_callers_serialize_correctly() {
    let exec = Executor::new(3);
    let want: u64 = (0..512u64).map(|i| i * i).sum();
    std::thread::scope(|s| {
        for _ in 0..4 {
            let exec = &exec;
            s.spawn(move || {
                for _ in 0..100 {
                    let got: u64 = exec
                        .run_chunks(512, |r| r.map(|i| (i as u64) * (i as u64)).sum::<u64>())
                        .into_iter()
                        .sum();
                    assert_eq!(got, want);
                }
            });
        }
    });
}

#[test]
fn persistent_pool_contracts() {
    // Liveness bookkeeping first, while no other pool exists in-process.
    drop_joins_all_workers();
    pool_survives_chunk_panics();
    concurrent_callers_serialize_correctly();
    interleaved_batch_runs_stay_bit_identical();
}
