//! The snapshot/restore contract, end to end: for every `SolverKind`, a
//! solve snapshotted at ANY step boundary, serialized to the wire form (a
//! simulated process boundary), and restored — same or different executor
//! width — must finish bit-identically to the uninterrupted run.
//!
//! Three layers of evidence:
//! * a `Gen`-driven property sweep over (solver, grid kind, NFE, co-batch
//!   layout, boundary, thread counts), with every case's seed logged to
//!   `target/snapshot_prop_seeds.log` (uploaded as a CI artifact on
//!   failure; the failing `Gen` seed in the panic reproduces the case);
//! * fixed edge cases: NFE=1, snapshot right after `init`, snapshot on the
//!   final boundary, and restore after `retain_lanes` dropped lanes;
//! * checked-in golden fixtures per solver (schema-gated), plus the
//!   kill-and-restart server e2e against a real checkpoint file.

use sadiff::config::{SamplerConfig, ServerConfig, SolverKind};
use sadiff::coordinator::engine::{run_batch, BatchRun};
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::{SampleRequest, ServerCheckpoint};
use sadiff::exec::Executor;
use sadiff::jsonlite::{self, Value};
use sadiff::models::ModelEval;
use sadiff::prop_assert;
use sadiff::solvers::snapshot::{hex_to_f64s, StepperState};
use sadiff::testsupport::{check_logged, PropConfig, SnapshotCase};
use sadiff::workloads;
use std::sync::Arc;

const SEED_LOG: &str = "target/snapshot_prop_seeds.log";
const GOLDEN_PATH: &str = "rust/tests/fixtures/snapshot_golden.json";

fn req(id: u64, n: usize, seed: u64, cfg: &SamplerConfig) -> SampleRequest {
    SampleRequest {
        id,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: cfg.clone(),
        n,
        seed,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    }
}

/// Run a group to boundary `k` on `exec_before`, snapshot, round-trip the
/// snapshot through its wire form, restore on `exec_after`, finish, and
/// return the responses.
fn snapshot_roundtrip_run(
    cfg: &SamplerConfig,
    reqs: &[SampleRequest],
    k: usize,
    exec_before: &Executor,
    exec_after: &Executor,
) -> Vec<sadiff::coordinator::SampleResponse> {
    let wl = workloads::latent_analog();
    let model: Arc<dyn ModelEval> = Arc::from(wl.model());
    let mut run = BatchRun::new(model, &wl, cfg, reqs.to_vec(), exec_before);
    for _ in 0..k {
        run.step(exec_before);
    }
    let line = jsonlite::to_string(&run.snapshot());
    drop(run); // the "killed" process

    let v = jsonlite::parse(&line).expect("snapshot line parses");
    let model: Arc<dyn ModelEval> = Arc::from(wl.model());
    let mut resumed = BatchRun::restore(&v, model, exec_after).expect("restore");
    while !resumed.step(exec_after) {}
    resumed.finish()
}

#[test]
fn property_sweep_snapshot_restore_bit_identity() {
    // Per iteration: sample a point in (solver, grid kind, NFE 1..=20,
    // lane layout, snapshot boundary, restore-side thread count), assert
    // restore == uninterrupted bitwise. The failing Gen seed prints in the
    // panic and lands in the seed log.
    let cases = std::env::var("SADIFF_SNAPSHOT_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);
    check_logged(PropConfig { cases, seed: 0x5AD1FF }, SEED_LOG, |g| {
        let case = SnapshotCase::sample(g);
        let cfg = case.config();
        let reqs: Vec<SampleRequest> = case
            .lane_counts
            .iter()
            .zip(&case.seeds)
            .enumerate()
            .map(|(i, (n, seed))| req(i as u64, *n, *seed, &cfg))
            .collect();

        let wl = workloads::latent_analog();
        let model = wl.model();
        let want = run_batch(&*model, &wl, &cfg, &reqs);

        let m = cfg.steps_for_nfe();
        let k = case.boundary(m);
        let got = snapshot_roundtrip_run(
            &cfg,
            &reqs,
            k,
            &Executor::new(case.threads_before),
            &Executor::new(case.threads_after),
        );
        prop_assert!(got.len() == want.len(), "{}: response count", case.describe());
        for (a, b) in want.iter().zip(&got) {
            prop_assert!(
                a.samples == b.samples,
                "{}: boundary {k}/{m} diverged for id {}",
                case.describe(),
                a.id
            );
            prop_assert!(
                a.nfe == b.nfe,
                "{}: NFE {} != {} after restore",
                case.describe(),
                a.nfe,
                b.nfe
            );
        }
        Ok(())
    });
}

#[test]
fn edge_nfe_1_and_snapshot_before_any_step() {
    // NFE=1 (no history beyond the warm-up) and snapshot immediately after
    // `init`, before any step — for every solver, at both restore widths.
    let wl = workloads::latent_analog();
    for kind in SolverKind::all() {
        for nfe in [1usize, 8] {
            let mut cfg = SamplerConfig::for_solver(*kind);
            cfg.nfe = nfe;
            let reqs = [req(0, 3, 900, &cfg), req(1, 2, 901, &cfg)];
            let model = wl.model();
            let want = run_batch(&*model, &wl, &cfg, &reqs);
            for threads_after in [1usize, 4] {
                let got = snapshot_roundtrip_run(
                    &cfg,
                    &reqs,
                    0,
                    &Executor::sequential(),
                    &Executor::new(threads_after),
                );
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(
                        a.samples, b.samples,
                        "{kind:?} nfe={nfe}: snapshot-after-init diverged (threads_after={threads_after})"
                    );
                    assert_eq!(a.nfe, b.nfe, "{kind:?} nfe={nfe}: NFE diverged");
                }
            }
        }
    }
}

#[test]
fn edge_snapshot_on_the_final_boundary() {
    // Snapshot after the LAST step: restore runs zero steps, only
    // `finish`, and must still reproduce the uninterrupted output.
    let wl = workloads::latent_analog();
    for kind in SolverKind::all() {
        let mut cfg = SamplerConfig::for_solver(*kind);
        cfg.nfe = 9;
        let reqs = [req(0, 4, 77, &cfg)];
        let model = wl.model();
        let want = run_batch(&*model, &wl, &cfg, &reqs);
        let m = cfg.steps_for_nfe();
        let got =
            snapshot_roundtrip_run(&cfg, &reqs, m, &Executor::new(2), &Executor::new(4));
        assert_eq!(want[0].samples, got[0].samples, "{kind:?}: final-boundary snapshot");
        assert_eq!(want[0].nfe, got[0].nfe, "{kind:?}: NFE diverged");
    }
}

#[test]
fn edge_cancel_then_snapshot_then_resume() {
    // Cancel the middle request halfway through (exercising every
    // stepper's `retain_lanes`), snapshot the survivors, restore at a
    // different width, resume: both survivors must equal their solo runs.
    let wl = workloads::latent_analog();
    for kind in SolverKind::all() {
        let mut cfg = SamplerConfig::for_solver(*kind);
        cfg.nfe = 10;
        let reqs = [req(0, 3, 41, &cfg), req(1, 4, 42, &cfg), req(2, 2, 43, &cfg)];
        let model = wl.model();
        let solo_a = run_batch(&*model, &wl, &cfg, &reqs[0..1]);
        let solo_c = run_batch(&*model, &wl, &cfg, &reqs[2..3]);

        let exec = Executor::new(3);
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let mut run = BatchRun::new(model, &wl, &cfg, reqs.to_vec(), &exec);
        let half = run.progress().1 / 2;
        for _ in 0..half {
            run.step(&exec);
        }
        run.cancel(1).expect("middle request in flight");
        let line = jsonlite::to_string(&run.snapshot());
        drop(run);

        let v = jsonlite::parse(&line).unwrap();
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let exec2 = Executor::new(4);
        let mut resumed = BatchRun::restore(&v, model, &exec2).unwrap();
        assert_eq!(resumed.tickets(), vec![0, 2], "{kind:?}");
        while !resumed.step(&exec2) {}
        let got = resumed.finish();
        assert_eq!(got.len(), 2, "{kind:?}");
        assert_eq!(got[0].samples, solo_a[0].samples, "{kind:?}: survivor A after restore");
        assert_eq!(got[1].samples, solo_c[0].samples, "{kind:?}: survivor C after restore");
    }
}

// ---------------------------------------------------------------------------
// Golden fixtures: a tiny checked-in checkpoint per solver. The fixtures
// pin the schema — if a field is renamed, a buffer reordered, or the hex
// encoding changed, restore (or the restore∘snapshot identity) breaks.
// ---------------------------------------------------------------------------

fn fixture_field<'a>(ck: &'a Value, key: &str) -> &'a Value {
    ck.get(key).unwrap_or_else(|| panic!("fixture checkpoint missing '{key}'"))
}

#[test]
fn golden_fixtures_restore_for_every_solver() {
    let text = std::fs::read_to_string(GOLDEN_PATH)
        .unwrap_or_else(|e| panic!("cannot read {GOLDEN_PATH}: {e}"));
    let file = jsonlite::parse(&text).unwrap();
    assert_eq!(
        file.req_usize("schema_version").unwrap() as u64,
        sadiff::solvers::snapshot::SNAPSHOT_SCHEMA_VERSION
    );
    let fixtures = file.get("fixtures").and_then(Value::as_array).expect("fixtures array");
    let mut seen: Vec<String> = Vec::new();
    for fx in fixtures {
        let name = fx.req_str("name").unwrap().to_string();
        let ck = fixture_field(fx, "checkpoint");
        let wl = workloads::by_name(ck.req_str("workload").unwrap()).unwrap();

        // Restore must succeed, at two executor widths, and both resumed
        // runs must agree bitwise (the migration contract, driven from a
        // checked-in artifact rather than a same-process snapshot).
        let mut outs = Vec::new();
        for threads in [1usize, 4] {
            let model: Arc<dyn ModelEval> = Arc::from(wl.model());
            let exec = Executor::new(threads);
            let mut run = BatchRun::restore(ck, model, &exec)
                .unwrap_or_else(|e| panic!("fixture '{name}' failed to restore: {e}"));
            while !run.step(&exec) {}
            let responses = run.finish();
            assert!(!responses.is_empty(), "{name}: no responses");
            let samples = responses[0].samples.clone().expect("samples returned");
            assert!(
                samples.iter().all(|v| v.is_finite()),
                "{name}: non-finite samples after restore"
            );
            outs.push(samples);
        }
        assert_eq!(outs[0], outs[1], "{name}: restored runs disagree across widths");

        // restore ∘ snapshot is the identity on the serialized state: the
        // re-taken snapshot must carry exactly the fixture's stepper state,
        // evolved x, grid position and noise streams.
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let run = BatchRun::restore(ck, model, &Executor::sequential()).unwrap();
        let resnap = run.snapshot();
        assert_eq!(
            StepperState::from_json(fixture_field(&resnap, "stepper")).unwrap(),
            StepperState::from_json(fixture_field(ck, "stepper")).unwrap(),
            "{name}: stepper state changed across restore∘snapshot"
        );
        for key in ["x", "next_step", "evals"] {
            assert_eq!(
                fixture_field(&resnap, key),
                fixture_field(ck, key),
                "{name}: '{key}' changed across restore∘snapshot"
            );
        }
        for key in ["stream_keys", "stream_locals"] {
            assert_eq!(
                fixture_field(&resnap, key),
                fixture_field(ck, key),
                "{name}: '{key}' changed across restore∘snapshot"
            );
        }
        // The embedded x payload decodes to the advertised shape.
        let lanes = fixture_field(ck, "stream_keys").as_array().unwrap().len();
        let dim = ck.req_usize("dim").unwrap();
        assert_eq!(
            hex_to_f64s(ck.req_str("x").unwrap()).unwrap().len(),
            lanes * dim,
            "{name}: x payload shape"
        );
        seen.push(name);
    }
    // Every solver in the zoo has a fixture.
    for kind in SolverKind::all() {
        assert!(
            seen.iter().any(|s| s == kind.name()),
            "no golden fixture for solver '{}'",
            kind.name()
        );
    }
}

#[test]
fn golden_fixture_schema_gate_is_a_typed_error() {
    let text = std::fs::read_to_string(GOLDEN_PATH).unwrap();
    let file = jsonlite::parse(&text).unwrap();
    let fixtures = file.get("fixtures").and_then(Value::as_array).unwrap();
    let mut ck = fixture_field(&fixtures[0], "checkpoint").clone();
    if let Value::Object(fields) = &mut ck {
        for (k, v) in fields.iter_mut() {
            if k == "schema_version" {
                *v = Value::Num(999.0);
            }
        }
    }
    let wl = workloads::latent_analog();
    let model: Arc<dyn ModelEval> = Arc::from(wl.model());
    let err = BatchRun::restore(&ck, model, &Executor::sequential()).unwrap_err();
    assert!(err.to_string().contains("newer"), "want a typed schema error, got: {err}");
}

/// Regenerate the golden fixture file from REAL mid-run snapshots (one per
/// solver, snapshotted at step 2 of an NFE=6 solve). Run manually after an
/// intentional schema or solver change:
/// `cargo test -q --test integration_snapshot -- --ignored regenerate`
#[test]
#[ignore]
fn regenerate_golden_fixtures() {
    let wl = workloads::latent_analog();
    let exec = Executor::sequential();
    let mut fixtures = Vec::new();
    for (i, kind) in SolverKind::all().iter().enumerate() {
        let mut cfg = SamplerConfig::for_solver(*kind);
        cfg.nfe = 6;
        let reqs = vec![req(31337 + i as u64, 2, 4242 + i as u64, &cfg)];
        let model: Arc<dyn ModelEval> = Arc::from(wl.model());
        let mut run = BatchRun::new(model, &wl, &cfg, reqs, &exec);
        for _ in 0..2 {
            run.step(&exec);
        }
        fixtures.push(Value::obj(vec![
            ("name", Value::Str(kind.name().into())),
            ("checkpoint", run.snapshot()),
        ]));
    }
    let file = Value::obj(vec![
        (
            "schema_version",
            Value::Num(sadiff::solvers::snapshot::SNAPSHOT_SCHEMA_VERSION as f64),
        ),
        ("fixtures", Value::Array(fixtures)),
    ]);
    std::fs::write(GOLDEN_PATH, format!("{}\n", jsonlite::to_string(&file))).unwrap();
    println!("rewrote {GOLDEN_PATH}");
}

// ---------------------------------------------------------------------------
// Kill-and-restart e2e: a server with an in-flight group is hard-killed
// mid-solve; a second server on the same checkpoint path resumes it and the
// recovered result is bit-identical to an uninterrupted run.
// ---------------------------------------------------------------------------

fn checkpointing_config(path: &str) -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        batch_deadline_ms: 3,
        workers: 1,
        queue_cap: 64,
        threads: 1,
        max_inflight: 2,
        presets_path: None,
        checkpoint_path: Some(path.to_string()),
        checkpoint_every: 20,
        ..ServerConfig::default()
    }
}

#[test]
fn kill_and_restart_recovers_bit_identical_results() {
    let dir = std::env::temp_dir().join(format!("sadiff_killtest_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck_path = dir.join("serve.ck.json");
    let ck_path = ck_path.to_str().unwrap().to_string();

    // The uninterrupted reference, computed engine-side (the server's
    // batch path is bit-identical to run_batch by the engine's contract).
    let cfg = SamplerConfig { nfe: 2500, ..SamplerConfig::sa_default() };
    let long_req = req(31337, 512, 31337, &cfg);
    let wl = workloads::by_name(&long_req.workload).unwrap();
    let model = wl.model();
    let want = run_batch(&*model, &wl, &cfg, &[long_req.clone()]);

    // --- Server A: admit the long solve, wait for a couple of checkpoint
    // writes, then hard-kill it mid-flight (simulated crash).
    let handle_a = Server::bind(checkpointing_config(&ck_path)).unwrap().spawn().unwrap();
    let addr_a = handle_a.addr.to_string();
    {
        // The requesting connection never gets a reply (the server dies);
        // detach it rather than joining.
        let addr = addr_a.clone();
        let r = long_req.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let _ = client.request(&r);
        });
    }
    let mut killed_mid_flight = false;
    for _ in 0..600 {
        std::thread::sleep(std::time::Duration::from_millis(10));
        let mut client = Client::connect(&addr_a).unwrap();
        let stats = client.stats().unwrap();
        if stats.req_f64("checkpoints_written").unwrap() >= 3.0
            && stats.req_f64("inflight_lanes").unwrap() >= 512.0
        {
            killed_mid_flight = true;
            break;
        }
    }
    assert!(killed_mid_flight, "server never checkpointed the in-flight group");
    handle_a.kill();
    // Give A's worker thread a moment to observe the abort flag and stop
    // touching the checkpoint file before B takes it over.
    std::thread::sleep(std::time::Duration::from_millis(300));

    // The checkpoint file survived the crash and names our group.
    let ck = ServerCheckpoint::load(&ck_path).unwrap();
    assert_eq!(ck.groups.len(), 1, "expected exactly the in-flight group");
    assert!(
        ck.groups[0].clients.iter().any(|(_, c)| *c == 31337),
        "checkpoint lost the client id"
    );

    // --- Server B: same checkpoint path; it must resume the group and park
    // the finished result in the recover store under the client id.
    let handle_b = Server::bind(checkpointing_config(&ck_path)).unwrap().spawn().unwrap();
    let addr_b = handle_b.addr.to_string();
    let mut recovered: Option<sadiff::coordinator::SampleResponse> = None;
    for _ in 0..1200 {
        std::thread::sleep(std::time::Duration::from_millis(25));
        let mut client = Client::connect(&addr_b).unwrap();
        let v = client.recover(Some(31337)).unwrap();
        if v.opt_bool("ok", false) {
            recovered = Some(sadiff::coordinator::SampleResponse::from_json(&v).unwrap());
            break;
        }
        let msg = v.opt_str("error", "");
        assert!(
            msg.contains("pending") || msg.contains("no recovered result"),
            "unexpected recover reply: {}",
            jsonlite::to_string(&v)
        );
    }
    let recovered = recovered.expect("recovery never completed");
    assert_eq!(recovered.id, 31337);
    assert!(recovered.ok, "{:?}", recovered.error);
    assert_eq!(
        recovered.samples, want[0].samples,
        "recovered samples are not bit-identical to the uninterrupted run"
    );
    assert_eq!(recovered.nfe, want[0].nfe, "recovered NFE accounting diverged");

    // The recover listing names the id, and the metrics saw the recovery.
    let mut client = Client::connect(&addr_b).unwrap();
    let listing = client.recover(None).unwrap();
    assert!(listing.opt_bool("ok", false));
    let ready = listing.get("ready").and_then(Value::as_array).unwrap();
    assert!(
        ready.iter().any(|v| v.as_u64() == Some(31337)),
        "recover listing missing the id"
    );
    let stats = client.stats().unwrap();
    assert!(stats.req_f64("groups_recovered").unwrap() >= 1.0);
    assert!(stats.req_f64("checkpoints_written").unwrap() >= 1.0);

    // A graceful drain leaves an empty checkpoint behind — a further
    // restart must not resurrect finished work. The worker threads drain
    // asynchronously after shutdown() returns, so poll for the rewrite.
    handle_b.shutdown();
    let mut drained_empty = false;
    for _ in 0..200 {
        std::thread::sleep(std::time::Duration::from_millis(25));
        if ServerCheckpoint::load(&ck_path).unwrap().groups.is_empty() {
            drained_empty = true;
            break;
        }
    }
    assert!(drained_empty, "drained server left in-flight groups in the checkpoint");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn recover_on_a_fresh_server_is_clean() {
    // No checkpoint involved: the recover verbs answer cleanly instead of
    // erroring or hanging.
    let cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..ServerConfig::default() };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();
    let v = client.recover(None).unwrap();
    assert!(v.opt_bool("ok", false));
    assert_eq!(v.req_f64("pending").unwrap(), 0.0);
    assert!(v.get("ready").and_then(Value::as_array).unwrap().is_empty());
    let v = client.recover(Some(42)).unwrap();
    assert!(!v.opt_bool("ok", true));
    assert!(v.req_str("error").unwrap().contains("no recovered result"));
    handle.shutdown();
}
