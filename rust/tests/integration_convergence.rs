//! Theorem 5.1/5.2 convergence-order checks (deterministic component) and
//! the stochastic-component sanity from Appendix B.

use sadiff::exps::convergence::{fit_order, ode_orders, sde_w2};

#[test]
fn predictor_orders_match_theorem_5_1() {
    // τ=0: global error O(hˢ). Fitted slopes should be near s (generous
    // windows: constants and the fine-reference floor perturb the fit).
    let ms = [8usize, 16, 32, 64];
    for (s, lo, hi) in [(1usize, 0.7, 1.6), (2, 1.6, 2.8), (3, 2.3, 4.2)] {
        let (hs, errs) = ode_orders(s, 0, &ms);
        let order = fit_order(&hs, &errs);
        assert!(
            (lo..=hi).contains(&order),
            "predictor s={s}: fitted order {order} not in [{lo}, {hi}]; errs={errs:?}"
        );
    }
}

#[test]
fn corrector_raises_order_per_theorem_5_2() {
    // ŝ-step corrector: O(h^{ŝ+1}) — the corrected scheme at (s, ŝ=s)
    // must carry a higher fitted order than the predictor-only scheme,
    // and the (1,1) scheme should be ≈ 2nd order.
    let ms = [8usize, 16, 32, 64];
    let (hs, errs_pred) = ode_orders(1, 0, &ms);
    let (_, errs_corr) = ode_orders(1, 1, &ms);
    let o_pred = fit_order(&hs, &errs_pred);
    let o_corr = fit_order(&hs, &errs_corr);
    assert!(
        o_corr > o_pred + 0.5,
        "corrector gained no order: {o_pred} -> {o_corr}"
    );
    assert!((1.6..=3.0).contains(&o_corr), "o_corr={o_corr}");
}

#[test]
fn errors_decrease_monotonically_with_refinement() {
    let ms = [8usize, 16, 32, 64];
    for (s, c) in [(1usize, 0usize), (2, 0), (3, 3)] {
        let (_, errs) = ode_orders(s, c, &ms);
        for w in errs.windows(2) {
            assert!(
                w[1] < w[0] * 1.05,
                "(s={s}, c={c}): error grew under refinement: {errs:?}"
            );
        }
    }
}

#[test]
fn stochastic_distributional_error_shrinks() {
    // O(τh) component: terminal exact-W2 (1-D GMM) must drop markedly
    // from 8 to 64 steps at τ=1.
    let coarse = sde_w2(1.0, 8, 4000);
    let fine = sde_w2(1.0, 64, 4000);
    assert!(
        fine < coarse * 0.6,
        "W2 did not shrink with h: coarse={coarse} fine={fine}"
    );
}
