//! The allocation-free hot-path contract: after `Stepper::init`, stepping
//! any solver in the zoo performs ZERO heap allocations — the history
//! ring, scratch arena, noise buffer and per-step coefficient tables are
//! all sized at `init`, and the fused `linalg` kernels operate in place.
//!
//! Asserted with a counting global allocator, which counts process-wide:
//! everything lives in ONE `#[test]` so no concurrent test pollutes the
//! counter (this binary is registered with its own `[[test]] `target).
//!
//! Tracing (`sadiff::obs`) is compiled into the hot path from PR 7 on;
//! the step loop below opens a span around every step with the recorder
//! disabled (its default state), so this test also proves the
//! observability contract's "free when off" half: a disabled span costs
//! no allocations.

use sadiff::config::{Prediction, SamplerConfig, SolverKind, TauKind};
use sadiff::exec::Executor;
use sadiff::linalg::simd::{self, Dispatch};
use sadiff::models::{EvalCtx, ModelEval};
use sadiff::rng::normal::PhiloxNormal;
use sadiff::schedule::{timesteps, NoiseSchedule};
use sadiff::solvers::stepper::{make_stepper, Stepper};
use sadiff::solvers::{prior_sample, Grid};
use sadiff::testsupport::alloc::{alloc_count, CountingAlloc};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// A model that predicts x₀̂ = x (pure copy): evaluates without touching
/// the heap, so the measurement isolates the *stepper's* allocations.
struct CopyModel {
    dim: usize,
}

impl ModelEval for CopyModel {
    fn dim(&self) -> usize {
        self.dim
    }
    fn eval_batch(&self, xs: &[f64], _ctx: &EvalCtx, out: &mut [f64]) {
        out.copy_from_slice(xs);
    }
}

/// Drive `cfg` for `m` steps after `init` and return the allocation count
/// across the step loop (plus `finish`).
fn allocs_across_steps(cfg: &SamplerConfig, n: usize, dim: usize) -> u64 {
    let sch = NoiseSchedule::vp_linear();
    let model = CopyModel { dim };
    let m = cfg.steps_for_nfe();
    let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
    let mut noise = PhiloxNormal::new(7);
    let mut x = prior_sample(&grid, dim, n, &mut noise);
    let mut st = make_stepper(cfg, &sch);
    st.init(&model, &grid, &mut x, n, &mut noise);
    let before = alloc_count();
    for i in 0..m {
        // Disabled span (the recorder is never started in this binary):
        // must not allocate, or the assertion below localizes it here.
        let _span = sadiff::obs::trace::span("step", "test");
        st.step(&model, &grid, i, &mut x, n, &mut noise);
    }
    st.finish(&mut x);
    let allocs = alloc_count() - before;
    assert!(x.iter().all(|v| v.is_finite()), "{:?}: non-finite output", cfg.solver);
    allocs
}

/// Raw kernel-tier preamble: every tier available on this host (so on an
/// AVX2 machine the SIMD path itself, not just whatever `dispatch()`
/// picked) runs every fused kernel with zero heap allocations. The first
/// `dispatch()` call is warmed outside the counted region — it reads the
/// `SADIFF_SIMD` environment variable once, which may allocate, which is
/// exactly why `make_stepper` resolves it before `init` returns.
fn kernels_allocate_nothing_on_any_tier() {
    simd::dispatch();
    let n = 3 * simd::BLOCK + 7; // straddle cache blocks, non-trivial tail
    let x = vec![0.25; n];
    let xi = vec![0.5; n];
    let mut y = vec![1.0; n];
    let hist = vec![0.125; 4 * n];
    let offsets = [0usize, n, 2 * n, 3 * n];
    let b = [0.3, 0.2, 0.1, 0.05];
    for d in Dispatch::all_available() {
        let before = alloc_count();
        simd::axpy_into_with(d, 0.5, &x, &mut y);
        simd::sub_into_with(d, &hist[..n], &xi, &mut y);
        simd::scale_add_with(d, &mut y, 0.9, 0.1, &x);
        simd::fma_noise_with(d, &mut y, 0.2, &xi);
        simd::lincomb_into_with(d, 0.9, &x, Some((0.1, &xi)), &b, &hist, &offsets, &mut y);
        simd::lincomb_inplace_with(d, 0.9, &mut y, &b, &hist, &offsets);
        std::hint::black_box(simd::dot_relaxed_with(d, &x, &xi));
        let allocs = alloc_count() - before;
        assert_eq!(allocs, 0, "{}: {allocs} heap allocations in the kernel layer", d.label());
    }
}

/// The pool half of the contract: with a `threads > 1` executor warm
/// (pool workers spawned at `Executor::new`, first dispatch done),
/// further dispatches allocate nothing — publishing the epoch, waking the
/// parked workers, running the statically assigned chunks and waiting out
/// the completion latch are all heap-free (std's mutex/condvar are
/// futex-based on Linux), and `for_each_mut` computes chunk bounds
/// arithmetically instead of materializing a range table. Proven both on
/// bare dispatches and across a real two-shard solver step loop driven
/// through the pool, the same shape `coordinator::engine` dispatches per
/// step. The counter is process-wide, so worker-side allocations would be
/// caught too.
fn pooled_dispatch_allocates_nothing() {
    let exec = Executor::new(4);
    let mut items = [0u64; 4];
    exec.for_each_mut(&mut items, |i, v| *v = i as u64); // warm: pool + first epoch
    let before = alloc_count();
    for round in 0..200u64 {
        exec.for_each_mut(&mut items, |i, v| *v = v.wrapping_add(round ^ i as u64));
    }
    let allocs = alloc_count() - before;
    assert_eq!(allocs, 0, "pool dispatch: {allocs} heap allocations across 200 warm dispatches");

    // A pooled step loop, shaped like `BatchRun::step`: one stepper shard
    // per pool part, each advanced inside a `for_each_mut` dispatch.
    struct ShardState {
        st: Box<dyn Stepper>,
        x: Vec<f64>,
        noise: PhiloxNormal,
    }
    let sch = NoiseSchedule::vp_linear();
    let (n, dim) = (3usize, 4usize);
    let model = CopyModel { dim };
    let cfg = SamplerConfig::sa_default();
    let m = cfg.steps_for_nfe();
    let grid = Grid::new(&sch, timesteps(&sch, cfg.selector, m));
    let mut shards: Vec<ShardState> = (0..2)
        .map(|lane0| {
            let mut noise = PhiloxNormal::new(7 + lane0 as u64);
            let mut x = prior_sample(&grid, dim, n, &mut noise);
            let mut st = make_stepper(&cfg, &sch);
            st.init(&model, &grid, &mut x, n, &mut noise);
            ShardState { st, x, noise }
        })
        .collect();
    let before = alloc_count();
    for i in 0..m {
        exec.for_each_mut(&mut shards, |_, sh| {
            let _span = sadiff::obs::trace::span("shard_step", "test");
            sh.st.step(&model, &grid, i, &mut sh.x, n, &mut sh.noise);
        });
    }
    let allocs = alloc_count() - before;
    assert_eq!(allocs, 0, "pooled step loop: {allocs} heap allocations across {m} steps");
    for sh in &shards {
        assert!(sh.x.iter().all(|v| v.is_finite()), "non-finite pooled-step output");
    }
}

/// The "free when off" half of the observability contract in isolation:
/// with the recorder disabled, opening spans and recording cross-thread
/// intervals must never touch the heap.
fn disabled_tracing_allocates_nothing() {
    assert!(!sadiff::obs::trace::is_enabled(), "recorder must be off in this binary");
    let before = alloc_count();
    for _ in 0..1000 {
        let _span = sadiff::obs::trace::span("alloc_probe", "test");
        sadiff::obs::trace::record_since("alloc_probe_since", "test", 0);
    }
    let allocs = alloc_count() - before;
    assert_eq!(allocs, 0, "disabled tracer: {allocs} heap allocations across 1000 spans");
}

#[test]
fn stepper_step_allocates_nothing_after_init_for_every_solver() {
    // The tracer first, in isolation: a disabled span is one relaxed
    // load, no clock read, no allocation.
    disabled_tracing_allocates_nothing();

    // The kernel layer next, on every tier — if the stepper loop below
    // regressed, this localizes whether the kernels themselves leaked an
    // allocation or the driver did.
    kernels_allocate_nothing_on_any_tier();

    // The persistent executor pool: warm dispatches (bare and driving a
    // real step loop) are allocation-free with threads > 1.
    pooled_dispatch_allocates_nothing();

    // Per-solver defaults first: all nine SolverKinds.
    for kind in SolverKind::all() {
        let mut cfg = SamplerConfig::for_solver(*kind);
        cfg.nfe = 14;
        let allocs = allocs_across_steps(&cfg, 6, 4);
        assert_eq!(allocs, 0, "{kind:?}: {allocs} heap allocations across the step loop");
    }

    // Config-dependent branches: SA with an interval τ (ξ refilled on some
    // steps, re-zeroed on others), SA noise prediction, SA predictor-only
    // ODE, deep history (s = ŝ = 4), and UniPC predictor-only.
    let mut sa_interval = SamplerConfig::sa_default();
    sa_interval.nfe = 16;
    sa_interval.tau_kind = TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 };
    sa_interval.predictor_steps = 2;
    sa_interval.corrector_steps = 2;

    let mut sa_noise = SamplerConfig::sa_default();
    sa_noise.nfe = 12;
    sa_noise.prediction = Prediction::Noise;
    sa_noise.tau = 0.4;
    sa_noise.corrector_steps = 1;

    let mut sa_ode = SamplerConfig::sa_default();
    sa_ode.nfe = 12;
    sa_ode.tau = 0.0;
    sa_ode.corrector_steps = 0;

    let mut sa_deep = SamplerConfig::sa_default();
    sa_deep.nfe = 16;
    sa_deep.predictor_steps = 4;
    sa_deep.corrector_steps = 4;

    let mut unipc_p = SamplerConfig::for_solver(SolverKind::UniPc);
    unipc_p.nfe = 12;
    unipc_p.predictor_steps = 2;
    unipc_p.corrector_steps = 0;

    for (name, cfg) in [
        ("sa interval-tau", sa_interval),
        ("sa noise-prediction", sa_noise),
        ("sa predictor-only ODE", sa_ode),
        ("sa deep history", sa_deep),
        ("unipc corrector-off", unipc_p),
    ] {
        let allocs = allocs_across_steps(&cfg, 5, 3);
        assert_eq!(allocs, 0, "{name}: {allocs} heap allocations across the step loop");
    }
}
