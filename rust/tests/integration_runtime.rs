//! PJRT runtime integration: load the AOT artifacts and validate their
//! numerics against the native Rust implementations.
//!
//! Requires `make artifacts`; every test skips (with a loud message) when
//! the artifacts directory is absent so `cargo test` stays usable before
//! the first build.

use sadiff::gmm::Gmm;
use sadiff::jsonlite::Value;
use sadiff::models::{EvalCtx, ModelEval};
use sadiff::runtime::{HloModel, RuntimeHost};
use sadiff::util::close;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("SADIFF_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at '{dir}' (run `make artifacts`)");
        None
    }
}

/// Reconstruct the python-side GMM from the manifest metadata.
fn gmm_from_manifest(meta: &Value) -> Gmm {
    let g = meta.get("gmm").expect("manifest meta.gmm");
    let weights: Vec<f64> = g
        .get("weights")
        .and_then(Value::as_array)
        .unwrap()
        .iter()
        .filter_map(Value::as_f64)
        .collect();
    let grab2d = |key: &str| -> Vec<Vec<f64>> {
        g.get(key)
            .and_then(Value::as_array)
            .unwrap()
            .iter()
            .map(|row| row.as_array().unwrap().iter().filter_map(Value::as_f64).collect())
            .collect()
    };
    Gmm::new(weights, grab2d("means"), grab2d("vars"))
}

#[test]
fn gmm_artifact_matches_native_gmm() {
    let Some(dir) = artifacts_dir() else { return };
    let host = RuntimeHost::open(&dir).unwrap();
    let entry = host.registry.entry("gmm_denoiser").expect("manifest entry");
    let gmm = gmm_from_manifest(&entry.meta);
    let model = HloModel::from_manifest(host.clone(), "gmm_denoiser").unwrap();
    assert_eq!(model.dim(), gmm.dim);

    let mut rng = sadiff::rng::Xoshiro256pp::new(5);
    for (alpha, sigma) in [(0.95, 0.3), (0.6, 0.8), (0.1, 1.0)] {
        let xs = gmm.sample_marginal(&mut rng, 10, alpha, sigma);
        let ctx = EvalCtx { t: 0.5, alpha, sigma };
        let mut got = vec![0.0; xs.len()];
        model.eval_batch(&xs, &ctx, &mut got);
        let want = gmm.posterior_mean_batch(&xs, alpha, sigma);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                close(*g, *w, 5e-4, 5e-4),
                "(α={alpha}, σ={sigma}): artifact {g} vs native {w}"
            );
        }
    }
}

#[test]
fn gmm_artifact_handles_odd_batches() {
    // Padding/chunking: n < B and n > B must both match the native model.
    let Some(dir) = artifacts_dir() else { return };
    let host = RuntimeHost::open(&dir).unwrap();
    let entry = host.registry.entry("gmm_denoiser").unwrap();
    let gmm = gmm_from_manifest(&entry.meta);
    let batch = entry.inputs[0][0];
    let model = HloModel::from_manifest(host, "gmm_denoiser").unwrap();
    let mut rng = sadiff::rng::Xoshiro256pp::new(6);
    for n in [1usize, batch - 1, batch + 3] {
        let xs = gmm.sample_marginal(&mut rng, n, 0.7, 0.7);
        let ctx = EvalCtx { t: 0.5, alpha: 0.7, sigma: 0.7 };
        let mut got = vec![0.0; xs.len()];
        model.eval_batch(&xs, &ctx, &mut got);
        let want = gmm.posterior_mean_batch(&xs, 0.7, 0.7);
        for (g, w) in got.iter().zip(&want) {
            assert!(close(*g, *w, 5e-4, 5e-4), "n={n}: {g} vs {w}");
        }
    }
}

#[test]
fn sa_update_artifact_matches_native_update() {
    // The Pallas fused update must agree with the Rust-side fused update
    // (same formula both sides; this validates the whole compile path).
    let Some(dir) = artifacts_dir() else { return };
    let host = RuntimeHost::open(&dir).unwrap();
    let entry = host.registry.entry("sa_update").expect("sa_update entry").clone();
    let (s, b, d) = (
        entry.meta.req_usize("s").unwrap(),
        entry.meta.req_usize("batch").unwrap(),
        entry.meta.req_usize("dim").unwrap(),
    );
    let mut rng = sadiff::rng::Xoshiro256pp::new(7);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let buf: Vec<f32> = (0..s * b * d).map(|_| rng.normal() as f32).collect();
    let coeffs: Vec<f32> = (0..s).map(|_| rng.normal() as f32).collect();
    let xi: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let scal = vec![0.87f32, 0.31f32];

    let out = host
        .execute(
            "sa_update",
            vec![x.clone(), buf.clone(), coeffs.clone(), scal.clone(), xi.clone()],
        )
        .unwrap();
    // Native reference (f32 accumulation to match).
    for k in 0..b * d {
        let mut want = scal[0] * x[k] + scal[1] * xi[k];
        for j in 0..s {
            want += coeffs[j] * buf[j * b * d + k];
        }
        assert!(
            (out[0][k] - want).abs() < 2e-4 * (1.0 + want.abs()),
            "k={k}: artifact {} vs native {want}",
            out[0][k]
        );
    }
}

#[test]
fn dit_artifact_is_a_plausible_denoiser() {
    // The trained DiT, at low noise, should roughly preserve in-support
    // inputs (data prediction ≈ identity as σ→0 for trained regions) and
    // must produce finite outputs of the right shape everywhere.
    let Some(dir) = artifacts_dir() else { return };
    let host = RuntimeHost::open(&dir).unwrap();
    let entry = host.registry.entry("dit_denoiser").expect("entry").clone();
    let gmm = gmm_from_manifest(&entry.meta);
    let model = HloModel::from_manifest(host, "dit_denoiser").unwrap();
    let dim = model.dim();
    assert_eq!(dim, gmm.dim);

    let sch = sadiff::schedule::NoiseSchedule::vp_linear();
    let mut rng = sadiff::rng::Xoshiro256pp::new(8);
    let x0 = gmm.sample(&mut rng, 8);
    // Low-noise check.
    let t = 0.05;
    let (alpha, sigma) = (sch.alpha(t), sch.sigma(t));
    let xt: Vec<f64> = x0
        .iter()
        .map(|v| alpha * v + sigma * rng.normal())
        .collect();
    let ctx = EvalCtx { t, alpha, sigma };
    let mut got = vec![0.0; xt.len()];
    model.eval_batch(&xt, &ctx, &mut got);
    assert!(got.iter().all(|v| v.is_finite()));
    let err: f64 = got
        .iter()
        .zip(&x0)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
        / (x0.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-9));
    assert!(err < 0.6, "trained DiT far from identity at low noise: rel err {err}");
}

#[test]
fn unknown_artifact_fails_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let host = RuntimeHost::open(&dir).unwrap();
    let err = host.execute("no_such_artifact", vec![]).unwrap_err();
    assert!(err.to_string().contains("unknown artifact"), "{err}");
    // Bad input count also errors, not panics.
    let err = host.execute("gmm_denoiser", vec![]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
}
