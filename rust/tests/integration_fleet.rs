//! Multi-worker fleet integration: a router plus in-process workers,
//! exercised through migrations, crash failovers and seeded chaos.
//!
//! Every test's oracle is the same serving invariant the single-server
//! suite proves: per-lane counter-keyed noise streams make a request's
//! samples a pure function of its own (seed, config), so a run that was
//! migrated, killed-and-failed-over, or requeued from scratch must be
//! bit-identical to an uninterrupted solo run on one server.
//!
//! The chaos sweep logs every case seed to `target/fleet_seeds.log`
//! (uploaded as a CI artifact on failure); the logged seed regenerates
//! the whole `FaultPlan`, so a failure reproduces from the log alone.
//!
//! The CI lane runs this file with `--test-threads=1`; the tests are
//! written to tolerate (but not require) that.

use std::time::{Duration, Instant};

use sadiff::config::{SamplerConfig, ServerConfig, SolverKind};
use sadiff::coordinator::server::{Client, Server, ServerHandle};
use sadiff::coordinator::{GroupCheckpoint, SampleRequest, SampleResponse};
use sadiff::jsonlite::{parse, to_string, Value};
use sadiff::prop_assert;
use sadiff::testsupport::fleet::{FaultPlan, Fleet, FleetConfig};
use sadiff::testsupport::{check_logged, PropConfig};

// ---------------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------------

/// A direct (router-less) server used for solo reference runs and for
/// throughput calibration. The lane cap is effectively disabled so big
/// calibrated requests are never shed.
fn spawn_solo() -> (ServerHandle, String) {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_lane_cap: 1_000_000,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

fn request(n: usize, seed: u64, nfe: usize) -> SampleRequest {
    SampleRequest {
        id: seed,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig { nfe, ..SamplerConfig::sa_default() },
        n,
        seed,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    }
}

fn run_on(addr: &str, req: &SampleRequest) -> SampleResponse {
    let mut client = Client::connect(addr).unwrap();
    client.request(req).unwrap()
}

/// Measured serving throughput in lane-steps per millisecond. Tests size
/// their long-running requests from this instead of fixed lane counts, so
/// "long enough to kill mid-solve" holds on fast and slow machines alike.
fn calibrate(addr: &str) -> f64 {
    let probe = request(512, 0xCA11B, 50);
    let t0 = Instant::now();
    let resp = run_on(addr, &probe);
    assert!(resp.ok, "calibration probe failed: {:?}", resp.error);
    let elapsed_ms = (t0.elapsed().as_secs_f64() * 1e3).max(1.0);
    (probe.n * probe.cfg.nfe) as f64 / elapsed_ms
}

/// Lane count that keeps a request of `nfe` steps in flight for roughly
/// `target_ms` at the calibrated rate, clamped to a sane range.
fn slow_n(rate: f64, target_ms: f64, nfe: usize, max_n: usize) -> usize {
    ((rate * target_ms / nfe.max(1) as f64) as usize).clamp(64, max_n)
}

/// Fleet config for this suite: workers with the lane cap disabled (the
/// sweeps fire several calibrated requests concurrently) and frequent
/// checkpoints so failover always has a recent boundary to resume from.
fn fleet_cfg(workers: usize) -> FleetConfig {
    let base = FleetConfig::default();
    let server = ServerConfig { queue_lane_cap: 1_000_000, ..base.server.clone() };
    FleetConfig { workers, server, ..base }
}

/// Index of the first alive worker the router holds a cached group
/// checkpoint for — the group's current owner; panics on timeout.
fn cached_owner(fleet: &Fleet, timeout: Duration) -> usize {
    let t0 = Instant::now();
    loop {
        let stats = fleet.router_stats();
        if let Some(Value::Array(ws)) = stats.get("workers") {
            for (i, w) in ws.iter().enumerate() {
                if w.opt_bool("alive", false) && w.opt_usize("cached_groups", 0) > 0 {
                    return i;
                }
            }
        }
        assert!(
            t0.elapsed() < timeout,
            "no worker cached a group checkpoint within {timeout:?}: {}",
            to_string(&stats)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Block until the router has declared worker `i` dead.
fn wait_router_sees_dead(fleet: &Fleet, i: usize, timeout: Duration) {
    let t0 = Instant::now();
    loop {
        let stats = fleet.router_stats();
        let dead = matches!(
            stats.get("workers"),
            Some(Value::Array(ws)) if ws.get(i).is_some_and(|w| !w.opt_bool("alive", true))
        );
        if dead {
            return;
        }
        assert!(
            t0.elapsed() < timeout,
            "router never declared worker {i} dead: {}",
            to_string(&stats)
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Block until a direct worker stat reaches `min`.
fn wait_worker_stat(addr: &str, key: &str, min: f64, timeout: Duration) {
    let t0 = Instant::now();
    loop {
        if let Ok(mut c) = Client::connect(addr) {
            if let Ok(stats) = c.stats() {
                if stats.req_f64(key).unwrap_or(0.0) >= min {
                    return;
                }
            }
        }
        assert!(t0.elapsed() < timeout, "worker {addr} never reached {key} >= {min}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Poll a worker's recover store until client id `id` is ready, then
/// return the response — without removing it when `take` is false.
fn poll_recover(addr: &str, id: u64, take: bool, timeout: Duration) -> SampleResponse {
    let t0 = Instant::now();
    loop {
        let mut c = Client::connect(addr).unwrap();
        let v = if take { c.recover_take(id).unwrap() } else { c.recover(Some(id)).unwrap() };
        if v.opt_bool("ok", false) {
            return SampleResponse::from_json(&v).unwrap();
        }
        assert!(
            t0.elapsed() < timeout,
            "recover({id}) never became ready on {addr}: {}",
            to_string(&v)
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn router_counter(fleet: &Fleet, key: &str) -> f64 {
    fleet.router_stats().req_f64(key).unwrap_or(0.0)
}

// ---------------------------------------------------------------------------
// Router basics: round-trip, bit-identity, live registration
// ---------------------------------------------------------------------------

#[test]
fn router_roundtrip_register_and_bit_identity() {
    let (solo, solo_addr) = spawn_solo();
    let fleet = Fleet::spawn(fleet_cfg(2));

    let mut client = fleet.client();
    assert_eq!(client.round_trip(r#"{"cmd":"ping"}"#).unwrap(), r#"{"ok":true}"#);

    // A routed request must be bit-identical to the solo run: the router
    // re-tickets internally but the reply carries the client id back.
    let req = request(6, 4242, 10);
    let want = run_on(&solo_addr, &req);
    let got = client.request(&req).unwrap();
    assert!(got.ok, "{:?}", got.error);
    assert_eq!(got.id, req.id);
    assert_eq!(got.samples, want.samples, "routed samples differ from solo");

    // Live registration: a worker that dials in mid-flight joins the
    // registry and serves traffic without a router restart.
    let (extra, extra_addr) = spawn_solo();
    let reg = to_string(&Value::obj(vec![
        ("cmd", Value::Str("register".into())),
        ("addr", Value::Str(extra_addr.clone())),
        ("capabilities", Value::obj(vec![("max_batch", Value::Num(8.0))])),
    ]));
    let reply = parse(&client.round_trip(&reg).unwrap()).unwrap();
    assert!(reply.opt_bool("ok", false), "{}", to_string(&reply));
    assert_eq!(reply.req_f64("workers").unwrap(), 3.0);
    let stats = fleet.router_stats();
    let Some(Value::Array(ws)) = stats.get("workers") else { panic!("no workers array") };
    assert_eq!(ws.len(), 3);

    let req2 = request(5, 777, 8);
    let want2 = run_on(&solo_addr, &req2);
    let got2 = client.request(&req2).unwrap();
    assert!(got2.ok, "{:?}", got2.error);
    assert_eq!(got2.samples, want2.samples);

    assert_eq!(router_counter(&fleet, "requests"), 2.0);
    assert_eq!(router_counter(&fleet, "responses_ok"), 2.0);

    extra.shutdown();
    solo.shutdown();
}

// ---------------------------------------------------------------------------
// Property sweep: solver × NFE × lane layout × migrations × kill × chaos
// ---------------------------------------------------------------------------

#[test]
fn migration_and_kill_property_sweep_stays_bit_identical() {
    let (solo, solo_addr) = spawn_solo();
    let rate = calibrate(&solo_addr);

    check_logged(PropConfig { cases: 3, seed: 0xF1EE7 }, "target/fleet_seeds.log", |g| {
        // -- Sample the whole case up front (determinism: the generator
        //    must never be consulted after wall-clock-dependent work).
        let solver = *g.choice(SolverKind::all());
        let nfe = g.usize_in(6, 14);
        let cfg = SamplerConfig { nfe, ..SamplerConfig::for_solver(solver) };
        let steps = cfg.steps_for_nfe().max(1) as u64;
        let n_requests = g.usize_in(1, 3);
        let base_n = slow_n(rate, 350.0, nfe, 20_000);
        let reqs: Vec<SampleRequest> = (0..n_requests)
            .map(|i| {
                let factor = g.usize_in(1, 4);
                let n = (base_n * factor / 4).clamp(64, 20_000);
                let seed = g.usize_in(1, 1_000_000) as u64;
                SampleRequest {
                    id: 1_000 + i as u64,
                    n,
                    seed,
                    cfg: cfg.clone(),
                    ..request(n, seed, nfe)
                }
            })
            .collect();
        let rebalances = g.usize_in(0, 2);
        let reb_triggers: Vec<u64> =
            (0..rebalances).map(|_| g.usize_in(1, steps as usize) as u64).collect();
        // Chaos plan over workers {0, 1} only; worker 2 always survives so
        // the fleet can never go fully dark mid-case.
        let plan_seed = g.usize_in(0, u32::MAX as usize) as u64;
        let plan = FaultPlan::generate(plan_seed, 2, steps);
        let kill = g.bool();
        let kill_worker = g.usize_in(0, 1);
        let kill_trigger = g.usize_in(1, steps as usize) as u64;

        // -- Solo references first (sequential, uncontended).
        let refs: Vec<SampleResponse> = reqs.iter().map(|r| run_on(&solo_addr, r)).collect();
        for (r, req) in refs.iter().zip(&reqs) {
            prop_assert!(r.ok, "solo reference failed for seed {}: {:?}", req.seed, r.error);
        }

        // -- The same requests through a fleet under chaos.
        let mut fleet = Fleet::spawn(fleet_cfg(3));
        let addr = fleet.router_addr();
        let joins: Vec<_> = reqs
            .iter()
            .cloned()
            .map(|r| {
                let addr = addr.clone();
                std::thread::spawn(move || Client::connect(&addr).unwrap().request(&r).unwrap())
            })
            .collect();

        fleet.run_plan(&plan);
        for t in &reb_triggers {
            fleet.wait_fleet_steps(*t, Duration::from_secs(2));
            // "no worker has in-flight work" is a legal no-op: the case's
            // work may already have drained past this trigger.
            let _ = fleet.rebalance();
        }
        if kill {
            fleet.wait_fleet_steps(kill_trigger, Duration::from_secs(2));
            fleet.kill_worker(kill_worker);
        }

        let got: Vec<SampleResponse> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        let ctx = format!(
            "solver={} nfe={} lanes={:?} rebalances={rebalances} kill={} {}",
            solver.name(),
            nfe,
            reqs.iter().map(|r| r.n).collect::<Vec<_>>(),
            if kill { format!("worker {kill_worker} at step {kill_trigger}") } else { "no".into() },
            plan.describe()
        );
        for (resp, want) in got.iter().zip(&refs) {
            prop_assert!(resp.ok, "routed request {} failed ({:?}) [{ctx}]", resp.id, resp.error);
            prop_assert!(
                resp.samples == want.samples,
                "request {} samples differ from solo run [{ctx}]",
                resp.id
            );
        }
        fleet.shutdown();
        Ok(())
    });
    solo.shutdown();
}

// ---------------------------------------------------------------------------
// Failover e2e: kill the owner mid-solve, survivor resumes the checkpoint
// ---------------------------------------------------------------------------

#[test]
fn failover_replays_checkpoint_bit_identically_exactly_once() {
    let (solo, solo_addr) = spawn_solo();
    let rate = calibrate(&solo_addr);
    let nfe = 200;
    let req = request(slow_n(rate, 1_500.0, nfe, 60_000), 90_001, nfe);
    let want = run_on(&solo_addr, &req);
    assert!(want.ok, "{:?}", want.error);

    let mut fleet = Fleet::spawn(fleet_cfg(2));
    let addr = fleet.router_addr();
    let join = {
        let (addr, req) = (addr.clone(), req.clone());
        std::thread::spawn(move || Client::connect(&addr).unwrap().request(&req).unwrap())
    };

    // Wait for the first published checkpoint to reach the router's cache
    // (that checkpoint is what failover re-assigns), then crash the owner.
    let owner = cached_owner(&fleet, Duration::from_secs(10));
    fleet.kill_worker(owner);

    let resp = join.join().unwrap();
    assert!(resp.ok, "failover reply not ok: {:?} kind {:?}", resp.error, resp.kind);
    assert_eq!(resp.id, req.id);
    assert_eq!(
        resp.samples, want.samples,
        "failed-over run is not bit-identical to the solo run"
    );

    // Exactly one client-visible reply, through exactly one failover.
    assert!(router_counter(&fleet, "failovers") >= 1.0);
    assert!(router_counter(&fleet, "groups_failed_over") >= 1.0);
    assert_eq!(router_counter(&fleet, "requests"), 1.0);
    assert_eq!(router_counter(&fleet, "responses_ok"), 1.0);
    assert_eq!(router_counter(&fleet, "responses_err"), 0.0);

    fleet.shutdown();
    solo.shutdown();
}

#[test]
fn double_failure_relocates_twice_and_still_lands_once() {
    let (solo, solo_addr) = spawn_solo();
    let rate = calibrate(&solo_addr);
    let nfe = 300;
    let req = request(slow_n(rate, 2_000.0, nfe, 60_000), 90_002, nfe);
    let want = run_on(&solo_addr, &req);
    assert!(want.ok, "{:?}", want.error);

    let mut fleet = Fleet::spawn(fleet_cfg(3));
    let addr = fleet.router_addr();
    let join = {
        let (addr, req) = (addr.clone(), req.clone());
        std::thread::spawn(move || Client::connect(&addr).unwrap().request(&req).unwrap())
    };

    // First crash: the checkpoint moves to a survivor (the router parks a
    // copy under the new owner the moment the hand-off is accepted).
    let owner = cached_owner(&fleet, Duration::from_secs(10));
    fleet.kill_worker(owner);
    // Second crash: the replacement dies too; the third worker finishes.
    let second = cached_owner(&fleet, Duration::from_secs(10));
    assert_ne!(second, owner, "cached group still attributed to the dead owner");
    fleet.kill_worker(second);

    let resp = join.join().unwrap();
    assert!(resp.ok, "double-failover reply not ok: {:?}", resp.error);
    assert_eq!(resp.samples, want.samples, "double failover broke bit-identity");
    assert!(router_counter(&fleet, "failovers") >= 2.0);
    assert_eq!(router_counter(&fleet, "responses_ok"), 1.0);

    fleet.shutdown();
    solo.shutdown();
}

#[test]
fn severed_migration_is_retried_and_stays_bit_identical() {
    let (solo, solo_addr) = spawn_solo();
    let rate = calibrate(&solo_addr);
    let nfe = 200;
    let req = request(slow_n(rate, 1_200.0, nfe, 60_000), 90_003, nfe);
    let want = run_on(&solo_addr, &req);

    let mut fleet = Fleet::spawn(fleet_cfg(2));
    let addr = fleet.router_addr();
    let join = {
        let (addr, req) = (addr.clone(), req.clone());
        std::thread::spawn(move || Client::connect(&addr).unwrap().request(&req).unwrap())
    };

    let owner = cached_owner(&fleet, Duration::from_secs(10));
    // Sever the next migrate_in hand-off: the failover's first placement
    // attempt dies mid-transfer and the router must retry from its cache.
    fleet.chaos.sever_next_migration();
    fleet.kill_worker(owner);

    let resp = join.join().unwrap();
    assert!(resp.ok, "severed failover reply not ok: {:?}", resp.error);
    assert_eq!(resp.samples, want.samples, "severed+retried failover broke bit-identity");
    assert!(router_counter(&fleet, "failovers") >= 1.0);
    assert!(router_counter(&fleet, "groups_failed_over") >= 1.0);

    fleet.shutdown();
    solo.shutdown();
}

#[test]
fn all_workers_dead_sheds_with_typed_retry_hint() {
    let mut fleet = Fleet::spawn(fleet_cfg(1));
    let mut client = fleet.client();
    let warm = client.request(&request(4, 5, 6)).unwrap();
    assert!(warm.ok, "{:?}", warm.error);

    fleet.kill_worker(0);
    wait_router_sees_dead(&fleet, 0, Duration::from_secs(5));

    let resp = client.request(&request(4, 6, 6)).unwrap();
    assert!(!resp.ok);
    assert_eq!(resp.kind.as_deref(), Some("shed"), "{:?}", resp.error);
    let hint = resp.retry_after_ms.expect("shed reply must carry retry_after_ms");
    assert!(hint >= 50, "retry hint too small: {hint}");
    assert!(router_counter(&fleet, "shed") >= 1.0);

    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Regression: stale recover-store entries across repeated migrations
// ---------------------------------------------------------------------------

/// Seed-era gap (a): a result left in a worker's recover store must not
/// be served for a client id whose *current* run was migrated away. The
/// migrate-out commit purges the store entry along with the ticket maps;
/// the new owner's store is the only exactly-once source.
#[test]
fn recover_after_migrate_away_does_not_serve_stale_results() {
    fn spawn_direct() -> (ServerHandle, String) {
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".into(),
            queue_lane_cap: 1_000_000,
            publish_snapshots: true,
            checkpoint_every: 8,
            ..ServerConfig::default()
        };
        let h = Server::bind(cfg).unwrap().spawn().unwrap();
        let addr = h.addr.to_string();
        (h, addr)
    }
    let (solo, solo_addr) = spawn_solo();
    let rate = calibrate(&solo_addr);
    let (home, home_addr) = spawn_direct();
    let (a, a_addr) = spawn_direct();
    let (b, b_addr) = spawn_direct();
    let nfe = 120;
    let n = slow_n(rate, 900.0, nfe, 60_000);

    let migrate_to = |from: &str, to: &str, client: u64| -> GroupCheckpoint {
        let reply = Client::connect(from).unwrap().migrate_out(Some(client), 8_000).unwrap();
        assert!(reply.opt_bool("ok", false), "migrate_out: {}", to_string(&reply));
        let gck = GroupCheckpoint::from_json(reply.get("group").unwrap()).unwrap();
        let acc = Client::connect(to).unwrap().migrate_in(&gck).unwrap();
        assert!(acc.opt_bool("ok", false), "migrate_in: {}", to_string(&acc));
        gck
    };
    let submit = |addr: &str, req: SampleRequest| {
        let addr = addr.to_string();
        std::thread::spawn(move || Client::connect(&addr).unwrap().request(&req).unwrap())
    };

    // Run 1: client id 77 starts on `home`, finishes on `a`, and its
    // result is *peeked* (no take) — deliberately left in a's store.
    let run1 = SampleRequest { id: 77, ..request(n, 111_111, nfe) };
    let join1 = submit(&home_addr, run1);
    wait_worker_stat(&home_addr, "inflight_lanes", 1.0, Duration::from_secs(5));
    migrate_to(&home_addr, &a_addr, 77);
    let r1 = join1.join().unwrap();
    assert_eq!(r1.kind.as_deref(), Some("migrated"), "{:?}", r1.error);
    let stale = poll_recover(&a_addr, 77, false, Duration::from_secs(10));
    assert!(stale.ok);

    // Run 2: the SAME client id, a different seed. home → a → (away) → b.
    let run2 = SampleRequest { id: 77, ..request(n, 222_222, nfe) };
    let want2 = run_on(&solo_addr, &run2);
    let join2 = submit(&home_addr, run2);
    wait_worker_stat(&home_addr, "inflight_lanes", 1.0, Duration::from_secs(5));
    migrate_to(&home_addr, &a_addr, 77);
    // Move run 2 off `a` while it is in flight there. This commit must
    // purge a's store entry for client 77 — the stale run-1 result.
    let reply = Client::connect(&a_addr).unwrap().migrate_out(Some(77), 8_000).unwrap();
    assert!(reply.opt_bool("ok", false), "migrate_out from a: {}", to_string(&reply));
    let gck = GroupCheckpoint::from_json(reply.get("group").unwrap()).unwrap();

    let after = Client::connect(&a_addr).unwrap().recover(Some(77)).unwrap();
    assert!(!after.opt_bool("ok", true), "stale recover entry survived: {}", to_string(&after));
    let msg = match after.get("error") {
        Some(Value::Str(s)) => s.clone(),
        other => format!("{other:?}"),
    };
    assert!(msg.contains("no recovered result"), "unexpected recover reply: {msg}");

    // The migrated run finishes on `b`; its take is the one true result.
    let acc = Client::connect(&b_addr).unwrap().migrate_in(&gck).unwrap();
    assert!(acc.opt_bool("ok", false), "migrate_in to b: {}", to_string(&acc));
    let r2 = join2.join().unwrap();
    assert_eq!(r2.kind.as_deref(), Some("migrated"), "{:?}", r2.error);
    let got2 = poll_recover(&b_addr, 77, true, Duration::from_secs(10));
    assert!(got2.ok);
    assert_eq!(got2.samples, want2.samples, "migrated twice, samples differ from solo");
    let gone = Client::connect(&b_addr).unwrap().recover_take(77).unwrap();
    assert!(!gone.opt_bool("ok", true), "second take must fail: {}", to_string(&gone));

    home.shutdown();
    a.shutdown();
    b.shutdown();
    solo.shutdown();
}

// ---------------------------------------------------------------------------
// Regression: cancel racing a migrate-out at the same step boundary
// ---------------------------------------------------------------------------

/// Seed-era gap (b): a lane cancelled at the same boundary a migrate-out
/// claims its group must be dropped exactly once — the cancel reply goes
/// to its waiting client, and the detached checkpoint must not carry the
/// cancelled request (which would resurrect it on the destination).
#[test]
fn cancel_racing_migrate_out_drops_the_lane_exactly_once() {
    let (solo, solo_addr) = spawn_solo();
    let rate = calibrate(&solo_addr);
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 2,
        batch_deadline_ms: 50,
        workers: 1,
        threads: 1,
        queue_lane_cap: 1_000_000,
        publish_snapshots: true,
        checkpoint_every: 8,
        ..ServerConfig::default()
    };
    let home = Server::bind(cfg).unwrap().spawn().unwrap();
    let home_addr = home.addr.to_string();
    let (dest, dest_addr) = spawn_solo();

    let nfe = 120;
    let n = slow_n(rate, 500.0, nfe, 30_000);
    let survivor = SampleRequest { id: 201, ..request(n, 333_333, nfe) };
    let victim = SampleRequest { id: 202, ..request(n, 444_444, nfe) };
    let want = run_on(&solo_addr, &survivor);

    let submit = |req: SampleRequest| {
        let addr = home_addr.clone();
        std::thread::spawn(move || Client::connect(&addr).unwrap().request(&req).unwrap())
    };
    let j_survivor = submit(survivor);
    let j_victim = submit(victim);

    // Both requests must be co-batched into ONE in-flight group, so the
    // cancel and the migrate-out contend for the same step boundary.
    wait_worker_stat(&home_addr, "inflight_lanes", 2.0 * n as f64, Duration::from_secs(5));
    let mut c = Client::connect(&home_addr).unwrap();
    assert_eq!(c.stats().unwrap().req_f64("inflight_groups").unwrap(), 1.0, "not co-batched");

    let cancel = c.cancel(202).unwrap();
    assert!(cancel.opt_bool("ok", false), "{}", to_string(&cancel));
    assert!(cancel.req_f64("cancel_pending").unwrap() >= 1.0, "{}", to_string(&cancel));
    let reply = c.migrate_out(Some(201), 8_000).unwrap();
    assert!(reply.opt_bool("ok", false), "migrate_out: {}", to_string(&reply));
    let gck = GroupCheckpoint::from_json(reply.get("group").unwrap()).unwrap();

    // The cancelled request must NOT ride along in the checkpoint.
    assert_eq!(gck.clients.len(), 1, "checkpoint clients: {:?}", gck.clients);
    assert_eq!(gck.clients[0].1, 201);

    // Exactly one reply each: the victim's is `cancelled`, the survivor's
    // is `migrated` (its result lands on the destination worker).
    let rv = j_victim.join().unwrap();
    assert_eq!(rv.kind.as_deref(), Some("cancelled"), "{:?}", rv.error);
    let rs = j_survivor.join().unwrap();
    assert_eq!(rs.kind.as_deref(), Some("migrated"), "{:?}", rs.error);

    let acc = Client::connect(&dest_addr).unwrap().migrate_in(&gck).unwrap();
    assert!(acc.opt_bool("ok", false), "migrate_in: {}", to_string(&acc));
    let got = poll_recover(&dest_addr, 201, true, Duration::from_secs(10));
    assert!(got.ok);
    assert_eq!(got.samples, want.samples, "survivor of the cancel race lost bit-identity");

    home.shutdown();
    dest.shutdown();
    solo.shutdown();
}
