//! Cross-tier bit-identity property suite for the kernel layer
//! (docs/KERNELS.md): every kernel tier the host can run must produce
//! **bitwise identical** results to the pinned-FP-order scalar reference
//! tier, across lane counts, dimensions, unaligned/remainder tails and
//! history orders — the proof obligation that lets the transparent
//! dispatch in `sadiff::linalg` sit underneath the system's bit-identity
//! contracts (stepper ≡ reference, snapshot goldens) without weakening
//! them. The one deliberate exception, the opt-in tolerance lane
//! `dot_relaxed`, is tested against its documented error bound instead.

use sadiff::linalg::simd::{self, Dispatch};

/// Deterministic non-trivial fill (no `rand` dependency): varied signs
/// and magnitudes so reassociation or FMA contraction in a wide tier
/// would actually change low-order bits.
fn fill(n: usize, seed: f64) -> Vec<f64> {
    (0..n).map(|k| ((k as f64 + seed) * 0.7310588).sin() * (1.0 + 0.01 * (k % 13) as f64)).collect()
}

/// Dimensions exercising every code-path shape: sub-lane lengths, exact
/// multiples of the 4-lane AVX2 width and the 8-wide portable reduction,
/// off-by-one remainder tails around both, and lengths that straddle the
/// cache-block boundary (`BLOCK` = 2048) on the blocked kernels.
fn dims() -> Vec<usize> {
    let mut d = vec![1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 100];
    for around in [simd::BLOCK, 2 * simd::BLOCK] {
        d.extend([around - 1, around, around + 1, around + 5]);
    }
    d
}

/// Run `check` on every non-reference tier available on this host, on
/// both an aligned slice of length `n` and a deliberately misaligned
/// view (`&v[1..]` of an `n + 1` buffer shifts the base pointer by 8
/// bytes off any 16/32-byte vector alignment), so the unaligned-load
/// paths and scalar tails are covered for every (tier, dim) pair.
fn for_each_tier_and_alignment(n: usize, mut check: impl FnMut(Dispatch, &[f64], &[f64], &[f64])) {
    let xa = fill(n, 0.3);
    let ya = fill(n, 7.1);
    let za = fill(n, 2.9);
    let xu = fill(n + 1, 0.3);
    let yu = fill(n + 1, 7.1);
    let zu = fill(n + 1, 2.9);
    for d in Dispatch::all_available() {
        if d == Dispatch::Scalar {
            continue;
        }
        check(d, &xa, &ya, &za);
        check(d, &xu[1..], &yu[1..], &zu[1..]);
    }
}

#[test]
fn elementwise_kernels_are_bitwise_identical_across_tiers() {
    for n in dims() {
        for_each_tier_and_alignment(n, |d, x, y, z| {
            let tier = d.label();

            let mut want = y.to_vec();
            simd::axpy_into_with(Dispatch::Scalar, 0.37, x, &mut want);
            let mut got = y.to_vec();
            simd::axpy_into_with(d, 0.37, x, &mut got);
            assert_eq!(got, want, "axpy_into: {tier} != scalar at n={n}");

            let mut want = vec![0.0; n];
            simd::sub_into_with(Dispatch::Scalar, x, y, &mut want);
            let mut got = vec![0.0; n];
            simd::sub_into_with(d, x, y, &mut got);
            assert_eq!(got, want, "sub_into: {tier} != scalar at n={n}");

            let mut want = y.to_vec();
            simd::scale_add_with(Dispatch::Scalar, &mut want, 0.93, -0.21, x);
            let mut got = y.to_vec();
            simd::scale_add_with(d, &mut got, 0.93, -0.21, x);
            assert_eq!(got, want, "scale_add: {tier} != scalar at n={n}");

            let mut want = z.to_vec();
            simd::fma_noise_with(Dispatch::Scalar, &mut want, 0.41, x);
            let mut got = z.to_vec();
            simd::fma_noise_with(d, &mut got, 0.41, x);
            assert_eq!(got, want, "fma_noise: {tier} != scalar at n={n}");
        });
    }
}

#[test]
fn lincomb_kernels_are_bitwise_identical_across_tiers_and_orders() {
    // Orders 1–4 hit the monomorphized scalar reference arms; 5 and 6
    // hit the dynamic arm. Offsets are deliberately out of slot order.
    let max_s = 6usize;
    for n in dims() {
        let hist = fill(max_s * (n + 1), 4.2);
        for s in 1..=max_s {
            let offsets: Vec<usize> = (0..s).map(|j| ((j * 2 + 3) % max_s) * n).collect();
            let b: Vec<f64> = (0..s).map(|j| 0.31 - 0.17 * j as f64).collect();
            for_each_tier_and_alignment(n, |d, x, xi, y| {
                let tier = d.label();

                for noise in [None, Some((0.23, xi))] {
                    let mut want = vec![0.0; n];
                    simd::lincomb_into_with(
                        Dispatch::Scalar,
                        0.91,
                        x,
                        noise,
                        &b,
                        &hist,
                        &offsets,
                        &mut want,
                    );
                    let mut got = vec![0.0; n];
                    simd::lincomb_into_with(d, 0.91, x, noise, &b, &hist, &offsets, &mut got);
                    let kind = if noise.is_some() { "noise" } else { "ode" };
                    assert_eq!(got, want, "lincomb_into({kind}): {tier} != scalar at n={n} s={s}");
                }

                let mut want = y.to_vec();
                simd::lincomb_inplace_with(Dispatch::Scalar, 0.91, &mut want, &b, &hist, &offsets);
                let mut got = y.to_vec();
                simd::lincomb_inplace_with(d, 0.91, &mut got, &b, &hist, &offsets);
                assert_eq!(got, want, "lincomb_inplace: {tier} != scalar at n={n} s={s}");
            });
        }
    }
}

#[test]
fn empty_history_and_empty_slices_are_handled_on_every_tier() {
    for d in Dispatch::all_available() {
        let x = [2.0, -4.0, 6.0];
        let mut out = [0.0; 3];
        simd::lincomb_into_with(d, 0.5, &x, None, &[], &[], &[], &mut out);
        assert_eq!(out, [1.0, -2.0, 3.0], "{}: s=0 is a pure scale", d.label());

        let mut empty_out: [f64; 0] = [];
        simd::lincomb_into_with(d, 0.5, &[], None, &[1.0], &[], &[0], &mut empty_out);
        simd::axpy_into_with(d, 1.0, &[], &mut empty_out);
        assert_eq!(simd::dot_relaxed_with(d, &[], &[]), 0.0, "{}: empty dot", d.label());
    }
}

#[test]
fn dot_relaxed_stays_within_its_documented_bound_on_every_tier() {
    // The tolerance lane: deterministic per tier, within the documented
    // reassociation bound of the sequential reference sum — far tighter
    // in practice, so the asserted 1e-12 relative slack is generous.
    for n in dims() {
        for_each_tier_and_alignment(n, |d, x, y, _| {
            let exact = simd::dot_relaxed_with(Dispatch::Scalar, x, y);
            let relaxed = simd::dot_relaxed_with(d, x, y);
            let scale: f64 = x.iter().zip(y).map(|(a, b)| (a * b).abs()).sum();
            assert!(
                (relaxed - exact).abs() <= 1e-12 * scale.max(1.0),
                "dot_relaxed: {} out of bound at n={n}: {relaxed} vs {exact}",
                d.label()
            );
            let again = simd::dot_relaxed_with(d, x, y);
            assert_eq!(relaxed, again, "dot_relaxed must be deterministic per tier");
        });
    }
}

#[test]
fn dispatch_selection_is_cached_consistent_and_reportable() {
    let d = simd::dispatch();
    assert!(d.available(), "dispatch() returned an unavailable tier");
    assert_eq!(d, simd::dispatch(), "dispatch() must be stable for the process");
    assert!(Dispatch::all_available().contains(&d));
    assert!(["env", "compile-time", "runtime"].contains(&simd::dispatch_source()));
    // The no-silent-fallback contract: a host that cannot run the widest
    // tier must say why (CI checks the same invariant on the bench
    // report); selecting AVX2 by detection means nothing was skipped.
    if d == Dispatch::Avx2 {
        assert!(simd::fallback_reason().is_none(), "avx2 selected but a fallback was recorded");
    } else if std::env::var("SADIFF_SIMD").is_err() {
        assert!(
            simd::fallback_reason().is_some(),
            "{} selected by detection without a logged fallback reason",
            d.label()
        );
    }

    // The reference tier and the portable tier run everywhere; the
    // transparent entry points must agree with whatever was selected.
    assert!(Dispatch::Scalar.available() && Dispatch::Portable.available());
    let x = fill(1000, 0.5);
    let mut via_dispatch = vec![0.0; 1000];
    sadiff::linalg::sub_into(&x, &x, &mut via_dispatch);
    let mut via_tier = vec![1.0; 1000];
    simd::sub_into_with(d, &x, &x, &mut via_tier);
    assert_eq!(via_dispatch, via_tier);
}
