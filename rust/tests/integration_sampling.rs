//! End-to-end sampling behaviour on the exact GMM workloads: the
//! paper-shape assertions that the experiment tables rely on.

use sadiff::config::{Prediction, SamplerConfig, SolverKind};
use sadiff::coordinator::engine::evaluate;
use sadiff::workloads;

#[test]
fn sa_solver_converges_with_nfe() {
    let wl = workloads::latent_analog();
    let model = wl.model();
    let fid = |nfe: usize| {
        let cfg = SamplerConfig { nfe, tau: 1.0, ..SamplerConfig::sa_default() };
        evaluate(&*model, &wl, &cfg, 2048, 3).sim_fid
    };
    let coarse = fid(6);
    let fine = fid(40);
    assert!(fine < coarse, "no improvement with NFE: {coarse} -> {fine}");
    assert!(fine < 0.5, "fine-NFE sim-FID too large: {fine}");
}

#[test]
fn data_prediction_beats_noise_prediction_at_low_nfe() {
    // Table 1's shape, mechanically guaranteed by Corollary A.2.
    let wl = workloads::latent_analog();
    let model = wl.model();
    let run = |pred| {
        let cfg = SamplerConfig {
            nfe: 12,
            tau: 1.0,
            prediction: pred,
            ..SamplerConfig::sa_default()
        };
        evaluate(&*model, &wl, &cfg, 2048, 1).sim_fid
    };
    let data = run(Prediction::Data);
    let noise = run(Prediction::Noise);
    assert!(
        data < noise,
        "data-prediction ({data}) should beat noise-prediction ({noise}) at low NFE"
    );
}

#[test]
fn corrector_improves_low_nfe_quality() {
    // Table 2's shape.
    let wl = workloads::cifar_analog();
    let model = wl.model();
    let run = |sp: usize, sc: usize| {
        let cfg = SamplerConfig {
            nfe: 15,
            tau: 0.4,
            predictor_steps: sp,
            corrector_steps: sc,
            ..SamplerConfig::sa_default()
        };
        evaluate(&*model, &wl, &cfg, 2048, 2).sim_fid
    };
    let p1 = run(1, 0);
    let p3c3 = run(3, 3);
    assert!(
        p3c3 < p1,
        "3-step P/C ({p3c3}) should beat 1-step predictor-only ({p1})"
    );
}

#[test]
fn moderate_nfe_sde_beats_ode() {
    // Figure 1's headline shape: at a moderate budget, τ≈1 beats τ=0.
    let wl = workloads::latent_analog();
    let model = wl.model();
    let run = |tau: f64| {
        let cfg = SamplerConfig { nfe: 40, tau, ..SamplerConfig::sa_default() };
        // Average over seeds to tame metric noise.
        (0..3)
            .map(|s| evaluate(&*model, &wl, &cfg, 2048, s).sim_fid)
            .sum::<f64>()
            / 3.0
    };
    let ode = run(0.0);
    let sde = run(1.0);
    assert!(
        sde < ode * 1.3,
        "SDE (tau=1, fid={sde}) should be at least comparable to ODE (fid={ode}) at NFE=40"
    );
}

#[test]
fn score_error_degrades_all_samplers_monotonically() {
    // Figure 4's ε axis: quality degrades with score error for every τ.
    use sadiff::models::{GmmAnalytic, PerturbedModel};
    let wl = workloads::cifar_analog();
    let run = |tau: f64, eps: f64| {
        let model = PerturbedModel::new(GmmAnalytic::new(wl.gmm.clone()), eps, 42);
        let cfg = SamplerConfig { nfe: 31, tau, ..SamplerConfig::sa_default() };
        (0..2)
            .map(|s| evaluate(&model, &wl, &cfg, 1024, s).sim_fid)
            .sum::<f64>()
            / 2.0
    };
    for tau in [0.0, 1.0] {
        let clean = run(tau, 0.0);
        let dirty = run(tau, 0.8);
        assert!(
            dirty > clean,
            "tau={tau}: score error should degrade quality ({clean} -> {dirty})"
        );
    }
}

#[test]
fn exogenous_error_amplification_scales_with_tau() {
    // The documented substrate deviation (fig4 module docs): with
    // exogenous additive model error, the SDE's larger per-step model
    // mass amplifies error — degradation grows with τ. This pins the
    // analysis so any future change in behaviour is surfaced.
    use sadiff::models::{GmmAnalytic, PerturbedModel};
    let wl = workloads::cifar_analog();
    let run = |tau: f64, eps: f64| {
        let model = PerturbedModel::new(GmmAnalytic::new(wl.gmm.clone()), eps, 42);
        let cfg = SamplerConfig { nfe: 31, tau, ..SamplerConfig::sa_default() };
        (0..2)
            .map(|s| evaluate(&model, &wl, &cfg, 1024, s).sim_fid)
            .sum::<f64>()
            / 2.0
    };
    let deg = |tau: f64| run(tau, 0.8) - run(tau, 0.0);
    let d0 = deg(0.0);
    let d1 = deg(1.0);
    assert!(
        d1 > d0,
        "SDE degradation ({d1}) should exceed ODE degradation ({d0}) under exogenous error"
    );
}

#[test]
fn all_solvers_reasonable_at_high_nfe() {
    // Every baseline must actually work: generous quality bar at NFE=63.
    let wl = workloads::latent_analog();
    let model = wl.model();
    for kind in SolverKind::all() {
        let cfg = SamplerConfig { nfe: 63, ..SamplerConfig::for_solver(*kind) };
        let row = evaluate(&*model, &wl, &cfg, 1024, 5);
        assert!(
            row.sim_fid.is_finite() && row.sim_fid < 5.0,
            "{kind:?}: sim_fid={} at NFE=63",
            row.sim_fid
        );
    }
}

#[test]
fn interval_tau_runs_on_ve_workload() {
    // The paper's piecewise-constant τ on the VE schedule (§E.1).
    use sadiff::config::TauKind;
    let wl = workloads::cifar_analog();
    let model = wl.model();
    let cfg = SamplerConfig {
        nfe: 23,
        tau: 0.8,
        tau_kind: TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 },
        ..SamplerConfig::sa_default()
    };
    let row = evaluate(&*model, &wl, &cfg, 1024, 9);
    assert!(row.sim_fid.is_finite() && row.sim_fid < 3.0, "fid={}", row.sim_fid);
}
