//! Property tests over the solver machinery (in-repo harness,
//! `testsupport`): randomized grids, τ shapes, orders and schedules.

use sadiff::config::Prediction;
use sadiff::gmm::Gmm;
use sadiff::lagrange::{exp_moments, lagrange_basis_coeffs, poly_eval};
use sadiff::models::GmmAnalytic;
use sadiff::rng::normal::PhiloxNormal;
use sadiff::schedule::{timesteps, NoiseSchedule, StepSelector};
use sadiff::solvers::coeffs::{coefficients, StepEnds};
use sadiff::solvers::sa::{SaSolver, SaSolverOpts};
use sadiff::solvers::Grid;
use sadiff::tau::TauFn;
use sadiff::testsupport::{check, PropConfig};
use sadiff::prop_assert;

fn random_ends(g: &mut sadiff::testsupport::Gen) -> StepEnds {
    let lam_s = g.f64_in(-3.0, 2.0);
    let lam_t = lam_s + g.f64_in(0.02, 1.5);
    let alpha = |l: f64| (1.0 / (1.0 + (-2.0 * l).exp())).sqrt();
    StepEnds {
        lam_s,
        lam_t,
        alpha_s: alpha(lam_s),
        alpha_t: alpha(lam_t),
        sigma_s: (1.0 - alpha(lam_s).powi(2)).sqrt(),
        sigma_t: (1.0 - alpha(lam_t).powi(2)).sqrt(),
    }
}

fn random_tau(g: &mut sadiff::testsupport::Gen) -> TauFn {
    match g.usize_in(0, 2) {
        0 => TauFn::Constant(g.f64_in(0.0, 1.8)),
        1 => TauFn::interval_from_sigma(g.f64_in(0.1, 1.5), 0.05, 1.0),
        _ => TauFn::Linear { a: g.f64_in(0.0, 1.0), b: g.f64_in(-0.3, 0.3) },
    }
}

#[test]
fn prop_coefficient_mass_conservation() {
    // Σ_j b_j equals the one-node coefficient for ANY node layout — the
    // interpolation of a constant recovers the total integral mass.
    check(PropConfig { cases: 120, seed: 11 }, |g| {
        let ends = random_ends(g);
        let tau = random_tau(g);
        let s = g.usize_in(1, 4);
        let mut nodes = vec![ends.lam_s];
        for _ in 1..s {
            nodes.push(ends.lam_s - g.f64_in(0.05, 1.0) * g.usize_in(1, 3) as f64);
        }
        nodes.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
        let pred = if g.bool() { Prediction::Data } else { Prediction::Noise };
        let full = coefficients(&nodes, &ends, &tau, pred);
        let one = coefficients(&[nodes[0]], &ends, &tau, pred);
        let total: f64 = full.b.iter().sum();
        prop_assert!(
            (total - one.b[0]).abs() < 1e-8 * (1.0 + one.b[0].abs()),
            "mass mismatch: Σb={total} vs {} (nodes {nodes:?}, tau {tau:?}, {pred:?})",
            one.b[0]
        );
        Ok(())
    });
}

#[test]
fn prop_noise_std_nonnegative_and_bounded() {
    // σ̃ ≥ 0 always; for data prediction σ̃ ≤ σ_t (Prop 4.2); Corollary A.2:
    // noise-prediction σ̃ dominates data-prediction σ̃.
    check(PropConfig { cases: 150, seed: 12 }, |g| {
        let ends = random_ends(g);
        let tau = random_tau(g);
        let d = coefficients(&[ends.lam_s], &ends, &tau, Prediction::Data);
        let n = coefficients(&[ends.lam_s], &ends, &tau, Prediction::Noise);
        prop_assert!(d.sigma_tilde >= 0.0 && n.sigma_tilde >= 0.0, "negative σ̃");
        prop_assert!(
            d.sigma_tilde <= ends.sigma_t * (1.0 + 1e-12),
            "data σ̃ {} > σ_t {}",
            d.sigma_tilde,
            ends.sigma_t
        );
        prop_assert!(
            n.sigma_tilde >= d.sigma_tilde - 1e-12,
            "Cor A.2 violated: noise {} < data {} (tau {tau:?})",
            n.sigma_tilde,
            d.sigma_tilde
        );
        Ok(())
    });
}

#[test]
fn prop_lagrange_partition_of_unity() {
    check(PropConfig { cases: 100, seed: 13 }, |g| {
        let s = g.usize_in(1, 5);
        let nodes = g.increasing(s, -4.0, 0.0);
        let cs = lagrange_basis_coeffs(&nodes);
        let u = g.f64_in(-4.5, 0.5);
        let total: f64 = cs.iter().map(|c| poly_eval(c, u)).sum();
        prop_assert!((total - 1.0).abs() < 1e-7, "Σ l_j({u}) = {total}, nodes {nodes:?}");
        Ok(())
    });
}

#[test]
fn prop_exp_moments_sign_and_magnitude() {
    // I_k(a, h) has sign (−1)^k (integrand over negative u) and
    // |I_k| ≤ h^k · |I_0-ish envelope|.
    check(PropConfig { cases: 120, seed: 14 }, |g| {
        let a = g.f64_in(-3.0, 3.0);
        let h = g.f64_in(1e-4, 2.0);
        let ms = exp_moments(a, h, 4);
        for (k, m) in ms.iter().enumerate() {
            let sign_ok = if k % 2 == 0 { *m >= 0.0 } else { *m <= 0.0 };
            prop_assert!(sign_ok, "I_{k}(a={a}, h={h}) = {m} has wrong sign");
            prop_assert!(
                m.abs() <= h.powi(k as i32) * h * (a.abs() * h).exp() + 1e-12,
                "I_{k}(a={a}, h={h}) = {m} too large"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_solver_finite_on_random_configs() {
    // Any (schedule, selector, order, τ, M) combination must produce
    // finite samples — no NaN/Inf escape hatches.
    check(PropConfig { cases: 40, seed: 15 }, |g| {
        let sch = *g.choice(&[
            NoiseSchedule::vp_linear(),
            NoiseSchedule::vp_cosine(),
            NoiseSchedule::ve(),
            NoiseSchedule::edm(),
        ]);
        let sel = *g.choice(&[
            StepSelector::UniformT,
            StepSelector::UniformLambda,
            StepSelector::EdmRho { rho: 7.0 },
        ]);
        let m = g.usize_in(2, 24);
        let grid = Grid::new(&sch, timesteps(&sch, sel, m));
        let opts = SaSolverOpts {
            predictor_steps: g.usize_in(1, 4),
            corrector_steps: g.usize_in(0, 4),
            prediction: if g.bool() { Prediction::Data } else { Prediction::Noise },
            tau: random_tau(g),
        };
        let model = GmmAnalytic::new(Gmm::structured(3, 2, 1.5, g.case as u64));
        let mut noise = PhiloxNormal::new(g.case as u64);
        let mut x = sadiff::solvers::prior_sample(&grid, 3, 4, &mut noise);
        SaSolver::new(opts.clone()).solve(&model, &grid, &mut x, 4, &mut noise);
        prop_assert!(
            x.iter().all(|v| v.is_finite()),
            "non-finite output: sch {:?} sel {sel:?} m {m} opts {opts:?}",
            sch.kind
        );
        // Data-prediction updates are convex-ish combinations of bounded
        // quantities — terminal states stay in a generous data envelope.
        // Noise prediction at coarse grids legitimately explodes (that IS
        // Table 1's phenomenon), so only finiteness is required there.
        if opts.prediction == Prediction::Data {
            let max = x.iter().fold(0.0f64, |acc, v| acc.max(v.abs()));
            prop_assert!(max < 100.0, "exploding samples: max |x| = {max} (opts {opts:?})");
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_lambda_inversion() {
    check(PropConfig { cases: 100, seed: 16 }, |g| {
        let sch = *g.choice(&[
            NoiseSchedule::vp_linear(),
            NoiseSchedule::vp_cosine(),
            NoiseSchedule::ve(),
            NoiseSchedule::edm(),
        ]);
        let t = g.f64_in(sch.t_min.max(1e-3), sch.t_max);
        let lam = sch.lambda(t);
        let t2 = sch.t_of_lambda(lam);
        prop_assert!(
            (t - t2).abs() < 1e-5 * (1.0 + t.abs()),
            "{:?}: t={t} → λ={lam} → t'={t2}",
            sch.kind
        );
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_fuzz() {
    // Random JSON value trees survive serialize → parse unchanged.
    use sadiff::jsonlite::{parse, to_string, Value};
    fn gen_value(g: &mut sadiff::testsupport::Gen, depth: usize) -> Value {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::Num((g.f64_in(-1e6, 1e6) * 64.0).round() / 64.0),
            3 => Value::Str(
                (0..g.usize_in(0, 8))
                    .map(|_| *g.choice(&['a', 'Ω', '"', '\\', '\n', 'z']))
                    .collect(),
            ),
            4 => Value::Array((0..g.usize_in(0, 4)).map(|_| gen_value(g, depth - 1)).collect()),
            _ => Value::Object(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), gen_value(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(PropConfig { cases: 200, seed: 77 }, |g| {
        let v = gen_value(g, 3);
        let s = to_string(&v);
        let back = parse(&s).map_err(|e| format!("parse failed on {s}: {e}"))?;
        prop_assert!(back == v, "roundtrip mismatch: {v:?} -> {s} -> {back:?}");
        Ok(())
    });
}

#[test]
fn prop_sampler_config_json_roundtrip() {
    use sadiff::config::{Prediction, SamplerConfig, SolverKind, TauKind};
    check(PropConfig { cases: 120, seed: 78 }, |g| {
        let mut cfg = SamplerConfig::for_solver(*g.choice(SolverKind::all()));
        cfg.nfe = g.usize_in(1, 200);
        cfg.tau = g.f64_in(0.0, 1.6);
        cfg.predictor_steps = g.usize_in(1, 6);
        cfg.corrector_steps = g.usize_in(0, 6);
        cfg.prediction = if g.bool() { Prediction::Data } else { Prediction::Noise };
        cfg.selector = *g.choice(sadiff::schedule::StepSelector::all());
        if g.bool() {
            cfg.tau_kind = TauKind::IntervalSigma { sigma_lo: 0.05, sigma_hi: 1.0 };
        }
        let back = SamplerConfig::from_json(&cfg.to_json())
            .map_err(|e| format!("rejected own serialization: {e}"))?;
        prop_assert!(back == cfg, "roundtrip mismatch: {cfg:?} vs {back:?}");
        Ok(())
    });
}

#[test]
fn prop_philox_batch_invariance() {
    // Per-lane noise never depends on how many lanes are drawn.
    use sadiff::rng::Philox4x32;
    check(PropConfig { cases: 60, seed: 79 }, |g| {
        let gen = Philox4x32::new(g.case as u64 * 7919);
        let lane = g.usize_in(0, 7) as u64;
        let step = g.usize_in(0, 100) as u64;
        let len_a = g.usize_in(1, 65);
        let len_b = g.usize_in(len_a, 130);
        let a = gen.normals(lane, step, len_a);
        let b = gen.normals(lane, step, len_b);
        prop_assert!(a[..] == b[..len_a], "prefix mismatch at lane {lane} step {step}");
        Ok(())
    });
}
