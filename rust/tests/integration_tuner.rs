//! Tuner → registry → server end-to-end: a tuned registry persisted to
//! disk, loaded by `serve --presets`-equivalent config, and resolved by
//! request `"preset"` fields must serve samples bit-identical to running
//! the winning config directly — at any lane-parallel thread count.

use sadiff::config::ServerConfig;
use sadiff::coordinator::engine;
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::SampleRequest;
use sadiff::exec::Executor;
use sadiff::jsonlite;
use sadiff::tuner::{tune, PresetRegistry, TuneOptions};
use sadiff::workloads;

fn tiny_opts() -> TuneOptions {
    TuneOptions { n: 96, ..TuneOptions::quick() }
}

/// Tune cifar_analog at two budgets and persist the registry to a temp
/// path; callers clean the directory up.
fn tuned_registry_on_disk(tag: &str) -> (PresetRegistry, std::path::PathBuf, std::path::PathBuf) {
    let reg = tune(
        &["cifar_analog".to_string()],
        &[5, 10],
        &tiny_opts(),
        &Executor::new(2),
    )
    .unwrap();
    let dir = std::env::temp_dir().join(format!("sadiff_tuner_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("presets.json");
    reg.save(path.to_str().unwrap()).unwrap();
    (reg, path, dir)
}

fn spawn_with_presets(
    path: &str,
    threads: usize,
    deadline_ms: u64,
) -> (sadiff::coordinator::ServerHandle, String) {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        batch_deadline_ms: deadline_ms,
        threads,
        presets_path: Some(path.to_string()),
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();
    (handle, addr)
}

fn preset_request(preset: &str, nfe_hint: usize, n: usize, seed: u64) -> SampleRequest {
    let mut req = SampleRequest::from_json(
        &jsonlite::parse(&format!(
            r#"{{"id": 1, "workload": "cifar_analog", "n": {n}, "seed": {seed},
                "return_samples": true, "preset": "{preset}",
                "solver": {{"nfe": {nfe_hint}}}}}"#
        ))
        .unwrap(),
    )
    .unwrap();
    req.id = seed;
    req
}

#[test]
fn preset_auto_serves_winning_config_bit_identical_at_any_thread_count() {
    let (reg, path, dir) = tuned_registry_on_disk("auto");

    // The expected samples: run the winning config for (cifar_analog,
    // budget nearest to the request's nfe=10) directly through the engine.
    let wl = workloads::by_name("cifar_analog").unwrap();
    let winner = reg.resolve("auto", "cifar_analog", 10).unwrap();
    assert_eq!(winner.budget, 10);
    let direct = engine::sample(&*wl.model(), &wl, &winner.cfg, 7, 4242);

    for threads in [1usize, 4] {
        let (handle, addr) = spawn_with_presets(path.to_str().unwrap(), threads, 2);
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.request(&preset_request("auto", 10, 7, 4242)).unwrap();
        assert!(resp.ok, "threads={threads}: {:?}", resp.error);
        assert_eq!(resp.nfe, direct.nfe, "threads={threads}");
        assert_eq!(
            resp.samples.as_deref(),
            Some(&direct.samples[..]),
            "threads={threads}: served preset samples diverge from direct run"
        );
        handle.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn preset_by_name_and_summary_roundtrip() {
    let (reg, path, dir) = tuned_registry_on_disk("name");
    let wl = workloads::by_name("cifar_analog").unwrap();
    let named = reg.resolve("cifar_analog@5", "cifar_analog", 999).unwrap();
    let direct = engine::sample(&*wl.model(), &wl, &named.cfg, 4, 99);

    let (handle, addr) = spawn_with_presets(path.to_str().unwrap(), 1, 2);
    let mut client = Client::connect(&addr).unwrap();

    // Exact-name resolution ignores the request's own nfe.
    let resp = client.request(&preset_request("cifar_analog@5", 40, 4, 99)).unwrap();
    assert!(resp.ok, "{:?}", resp.error);
    assert_eq!(resp.samples.as_deref(), Some(&direct.samples[..]));

    // Unknown preset → error listing what exists.
    let resp = client.request(&preset_request("nope@7", 10, 2, 1)).unwrap();
    assert!(!resp.ok);
    assert!(resp.error.as_ref().unwrap().contains("cifar_analog@5"));

    // The presets command reports the loaded registry.
    let v = jsonlite::parse(&client.round_trip(r#"{"cmd":"presets"}"#).unwrap()).unwrap();
    assert!(v.opt_bool("ok", false));
    assert_eq!(v.req_usize("count").unwrap(), 2);
    let names: Vec<&str> = v
        .get("presets")
        .unwrap()
        .as_array()
        .unwrap()
        .iter()
        .map(|p| p.req_str("name").unwrap())
        .collect();
    assert_eq!(names, vec!["cifar_analog@5", "cifar_analog@10"]);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn preset_and_manual_requests_share_a_batch() {
    // A preset request resolves at ingress to the same concrete config as
    // a manual request; the two must merge into one batch (observed via
    // the mean-occupancy metric) and still get per-request samples.
    let (reg, path, dir) = tuned_registry_on_disk("merge");
    let winner = reg.resolve("auto", "cifar_analog", 5).unwrap().cfg.clone();

    // A generous batching window so the four concurrent requests reliably
    // land in one flush.
    let (handle, addr) = spawn_with_presets(path.to_str().unwrap(), 1, 150);
    let mut joins = Vec::new();
    for seed in [21u64, 22, 23, 24] {
        let addr = addr.clone();
        let manual_cfg = winner.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let req = if seed % 2 == 0 {
                // Manual request with the winning config spelled out.
                SampleRequest {
                    id: seed,
                    workload: "cifar_analog".into(),
                    model: "gmm".into(),
                    cfg: manual_cfg,
                    n: 3,
                    seed,
                    return_samples: true,
                    want_metrics: false,
                    preset: None,
                    deadline_ms: None,
                    priority: 0,
                }
            } else {
                preset_request("auto", 5, 3, seed)
            };
            client.request(&req).unwrap()
        }));
    }
    let responses: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for r in &responses {
        assert!(r.ok, "{:?}", r.error);
        assert_eq!(r.samples.as_ref().unwrap().len(), 3 * r.dim);
    }
    let mut client = Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert!(
        stats.req_f64("mean_batch_occupancy").unwrap() > 1.0,
        "preset and manual requests never merged: {}",
        jsonlite::to_string(&stats)
    );
    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn registry_load_rejects_garbage() {
    let dir = std::env::temp_dir().join(format!("sadiff_tuner_bad_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.json");

    std::fs::write(&path, "{ not json").unwrap();
    assert!(PresetRegistry::load(path.to_str().unwrap()).is_err());

    std::fs::write(&path, r#"{"schema_version": 999, "presets": []}"#).unwrap();
    let err = PresetRegistry::load(path.to_str().unwrap()).unwrap_err();
    assert!(err.to_string().contains("newer"));

    // A server pointed at a bad registry fails to bind, loudly.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        presets_path: Some(path.to_str().unwrap().to_string()),
        ..ServerConfig::default()
    };
    assert!(Server::bind(cfg).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
