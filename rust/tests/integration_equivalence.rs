//! §5.3 reduction tests: SA-Solver special cases against independent
//! implementations (Corollary 5.3, §B.5.2, §B.5.3).

use sadiff::exps::equivalence;

#[test]
fn ddim_eta_equals_one_step_sa_predictor() {
    // Exact reduction (Corollary 5.3): per-step τ_η reproduces DDIM-η to
    // floating point for deterministic AND stochastic η.
    for eta in [0.0, 0.3, 0.7, 1.0] {
        let delta = equivalence::ddim_vs_sa(eta, 12);
        assert!(delta < 1e-9, "eta={eta}: delta={delta}");
    }
}

#[test]
fn pp2m_is_two_step_sa_predictor_to_scheme_order() {
    // DPM-Solver++(2M) uses the Taylor-truncated 2-step coefficients
    // (the paper's own Appendix-D implementation does the same); the gap
    // to the exact-integral SA-Predictor is O(h²) per step and must
    // shrink fast under refinement.
    // The per-step coefficient gap is O(h²) relative, so the accumulated
    // trajectory gap shrinks ~linearly in h.
    let d8 = equivalence::pp2m_vs_sa(8);
    let d32 = equivalence::pp2m_vs_sa(32);
    let d128 = equivalence::pp2m_vs_sa(128);
    assert!(d32 < d8 * 0.6, "no refinement: {d8} -> {d32}");
    assert!(d128 < d32, "no refinement: {d32} -> {d128}");
    assert!(d128 < 3e-3, "d128={d128}");
}

#[test]
fn unipc_p_equals_sa_solver_p_p() {
    // Same math, independent coefficient numerics (adaptive Simpson vs
    // exact moment recursion): must agree to quadrature tolerance.
    for p in [1usize, 2, 3] {
        let delta = equivalence::unipc_vs_sa(p, 12);
        assert!(delta < 1e-7, "p={p}: delta={delta}");
    }
}
