//! Loadgen end-to-end: closed- and open-loop traffic against a live
//! server — typed deadline/shed classification, priority ordering within
//! a compatibility group, the bench artifact shape, and bit-identity of
//! samples under loadgen pressure.

use sadiff::config::{SamplerConfig, ServerConfig};
use sadiff::coordinator::server::{Client, Server};
use sadiff::coordinator::SampleRequest;
use sadiff::jsonlite::{self, Value};
use sadiff::loadgen::{self, Arrival, LoadgenOptions};
use std::time::{Duration, Instant};

fn request(n: usize, seed: u64, nfe: usize) -> SampleRequest {
    SampleRequest {
        id: seed,
        workload: "latent_analog".into(),
        model: "gmm".into(),
        cfg: SamplerConfig { nfe, ..SamplerConfig::sa_default() },
        n,
        seed,
        return_samples: true,
        want_metrics: false,
        preset: None,
        deadline_ms: None,
        priority: 0,
    }
}

/// Poll-cancel `id` until the server reports it cancelled (queued or in
/// flight); panics if it never shows up.
fn cancel_until_hit(addr: &str, id: u64) {
    let mut client = Client::connect(addr).unwrap();
    for _ in 0..500 {
        let v = client.cancel(id).unwrap();
        if v.req_f64("cancelled_queued").unwrap() + v.req_f64("cancel_pending").unwrap() >= 1.0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("could not cancel request {id}");
}

#[test]
fn closed_loop_reports_goodput_latency_and_lane_util() {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 4,
        batch_deadline_ms: 3,
        workers: 2,
        queue_cap: 64,
        threads: 1,
        max_inflight: 4,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    let mut opts = LoadgenOptions::new(Arrival::Closed { concurrency: 3 });
    opts.max_requests = 18;
    opts.duration_s = 30.0;
    opts.nfe = 8;
    opts.n = 2;
    opts.seed = 1;
    let report = loadgen::run(&addr, &opts).unwrap();

    assert_eq!(report.sent, 18, "closed loop must honor the request cap");
    assert_eq!(report.ok, 18, "an unloaded server must answer everything");
    assert_eq!(report.latency.count(), 18);
    assert!(report.achieved_rps() > 0.0);
    assert!(report.goodput_rps() > 0.0);
    assert!(report.lane_util.steps > 0, "lane utilization must come from server stats");
    assert!(report.lane_util.mean_lanes_per_step() >= 1.0);

    // The bench artifact round-trips with non-null percentiles.
    let path = std::env::temp_dir().join(format!("sadiff_loadgen_{}.json", std::process::id()));
    let path_str = path.to_str().unwrap().to_string();
    loadgen::write_bench(&path_str, &[report]).unwrap();
    let doc = jsonlite::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
    assert_eq!(doc.req_f64("schema_version").unwrap(), 1.0);
    let points = doc.get("loadgen").unwrap().get("points").unwrap();
    let Value::Array(points) = points else { panic!("points must be an array") };
    let p = &points[0];
    assert_eq!(p.req_str("mode").unwrap(), "closed");
    assert!(matches!(p.get("offered_rps"), Some(Value::Null)), "closed loop has no offered rate");
    assert_eq!(p.req_f64("shed").unwrap(), 0.0);
    assert_eq!(p.req_f64("deadline_miss").unwrap(), 0.0);
    let p99 = p.get("latency").unwrap().get("p99_ms").unwrap().as_f64();
    assert!(p99.is_some_and(|v| v > 0.0), "p99 must be a finite number at smoke load");
    std::fs::remove_file(&path).ok();
    handle.shutdown();
}

#[test]
fn saturated_server_answers_with_typed_deadline_misses() {
    // One worker, one in-flight slot, held by a wide blocker: closed-loop
    // requests with a 100 ms budget queue behind it far past their
    // deadlines. When the blocker is cancelled, the scheduler must answer
    // the expired ones with typed `deadline` replies instead of burning
    // NFEs on them, and serve the rest normally.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        batch_deadline_ms: 1,
        workers: 1,
        queue_cap: 64,
        threads: 1,
        max_inflight: 1,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    let blocker_addr = addr.clone();
    let blocker = std::thread::spawn(move || {
        let mut client = Client::connect(&blocker_addr).unwrap();
        client.request(&request(1024, 900, 10_000)).unwrap()
    });
    std::thread::sleep(Duration::from_millis(150));
    let cancel_addr = addr.clone();
    let canceller = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(400));
        cancel_until_hit(&cancel_addr, 900);
    });

    let mut opts = LoadgenOptions::new(Arrival::Closed { concurrency: 2 });
    opts.max_requests = 8;
    opts.duration_s = 20.0;
    opts.nfe = 8;
    opts.n = 2;
    opts.deadline_ms = Some(100);
    opts.seed = 3;
    let report = loadgen::run(&addr, &opts).unwrap();

    assert_eq!(report.sent, 8);
    assert!(report.deadline_miss >= 1, "queued-past-deadline requests must be typed misses");
    assert!(report.ok >= 1, "post-blocker requests must be served");
    assert_eq!(
        report.other_error + report.timeout + report.shed,
        8 - report.ok - report.deadline_miss
    );

    let mut stats_client = Client::connect(&addr).unwrap();
    let stats = stats_client.stats().unwrap();
    assert!(stats.req_f64("deadline_miss").unwrap() >= 1.0);

    canceller.join().unwrap();
    assert!(!blocker.join().unwrap().ok, "the blocker must end cancelled");
    handle.shutdown();
}

#[test]
fn open_loop_overload_is_shed_not_hung() {
    // queue_cap 2 with the only worker blocked: a Poisson burst must be
    // answered promptly with typed `shed` replies (classified by the
    // loadgen), and the two requests that did get queue slots become
    // deadline misses once the blocker is cancelled — nothing hangs, every
    // arrival gets a definite outcome.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 1,
        batch_deadline_ms: 1,
        workers: 1,
        queue_cap: 2,
        queue_lane_cap: 1_000_000,
        threads: 1,
        max_inflight: 1,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    let blocker_addr = addr.clone();
    let blocker = std::thread::spawn(move || {
        let mut client = Client::connect(&blocker_addr).unwrap();
        client.request(&request(1024, 900, 10_000)).unwrap()
    });
    std::thread::sleep(Duration::from_millis(150));
    let cancel_addr = addr.clone();
    let canceller = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(700));
        cancel_until_hit(&cancel_addr, 900);
    });

    let mut opts = LoadgenOptions::new(Arrival::Poisson { rate_rps: 200.0 });
    opts.duration_s = 0.4;
    opts.nfe = 8;
    opts.n = 2;
    opts.deadline_ms = Some(300);
    opts.seed = 9;
    let report = loadgen::run(&addr, &opts).unwrap();

    assert_eq!(report.offered_rps, Some(200.0));
    assert!(report.sent >= 35, "Poisson(80) schedule came out far too short: {}", report.sent);
    assert!(report.shed >= 5, "overload must shed: {}", report.shed);
    assert_eq!(
        report.sent,
        report.ok + report.shed + report.deadline_miss + report.timeout + report.other_error,
        "every arrival needs a definite outcome"
    );

    canceller.join().unwrap();
    assert!(!blocker.join().unwrap().ok);
    handle.shutdown();
}

#[test]
fn high_priority_request_overtakes_earlier_low_priority_peers() {
    // Three compatible requests queue behind a blocker in arrival order
    // L1, L2, H(priority 5) with max_batch 2: the scheduler must seed the
    // group with H (plus L1 as FIFO tie-break), leaving L2 for the next
    // group — so H completes strictly before L2. Pre-fix FIFO extraction
    // admitted [L1, L2] first and H last.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 2,
        batch_deadline_ms: 200,
        workers: 1,
        queue_cap: 64,
        threads: 1,
        max_inflight: 1,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    let blocker_addr = addr.clone();
    let blocker = std::thread::spawn(move || {
        let mut client = Client::connect(&blocker_addr).unwrap();
        client.request(&request(1024, 900, 10_000)).unwrap()
    });
    std::thread::sleep(Duration::from_millis(150));

    let fire = |id: u64, priority: i64| {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut req = request(2, id, 8);
            req.priority = priority;
            let mut client = Client::connect(&addr).unwrap();
            let resp = client.request(&req).unwrap();
            (resp, Instant::now())
        })
    };
    let l1 = fire(1, 0);
    std::thread::sleep(Duration::from_millis(40));
    let l2 = fire(2, 0);
    std::thread::sleep(Duration::from_millis(40));
    let h = fire(3, 5);
    std::thread::sleep(Duration::from_millis(100));
    cancel_until_hit(&addr, 900);

    let (l1_resp, _t_l1) = l1.join().unwrap();
    let (l2_resp, t_l2) = l2.join().unwrap();
    let (h_resp, t_h) = h.join().unwrap();
    assert!(l1_resp.ok && l2_resp.ok && h_resp.ok);
    assert!(
        t_h < t_l2,
        "priority inversion: high-priority request finished after the earlier low-priority one"
    );
    assert!(!blocker.join().unwrap().ok);
    handle.shutdown();
}

#[test]
fn samples_stay_bit_identical_under_loadgen_pressure() {
    // Per-lane Philox noise keys make a request's samples independent of
    // whatever the scheduler co-batches it with. Re-issue the same seeded
    // request while a closed-loop loadgen floods the server with
    // *compatible* traffic (same BatchKey, so they really do merge) and
    // demand bitwise equality with the idle-server reference.
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        max_batch: 8,
        batch_deadline_ms: 3,
        workers: 2,
        queue_cap: 64,
        threads: 2,
        max_inflight: 4,
        presets_path: None,
        checkpoint_path: None,
        checkpoint_every: 16,
        ..ServerConfig::default()
    };
    let handle = Server::bind(cfg).unwrap().spawn().unwrap();
    let addr = handle.addr.to_string();

    let mut client = Client::connect(&addr).unwrap();
    let solo = client.request(&request(4, 4242, 12)).unwrap();
    assert!(solo.ok);
    assert!(solo.samples.is_some());

    let gen_addr = addr.clone();
    let generator = std::thread::spawn(move || {
        let mut opts = LoadgenOptions::new(Arrival::Closed { concurrency: 4 });
        opts.max_requests = 80;
        opts.duration_s = 30.0;
        opts.nfe = 12; // same cfg as the probe → same compatibility group
        opts.n = 4;
        opts.seed = 7000;
        loadgen::run(&gen_addr, &opts).unwrap()
    });

    for round in 0..5 {
        let probe = client.request(&request(4, 4242, 12)).unwrap();
        assert!(probe.ok, "round {round}: {:?}", probe.error);
        assert_eq!(
            probe.samples, solo.samples,
            "round {round}: loadgen pressure changed the probe's samples"
        );
    }

    let report = generator.join().unwrap();
    assert_eq!(report.sent, 80);
    assert_eq!(report.ok, 80, "compatible loadgen traffic must all succeed");
    handle.shutdown();
}
