"""Layer-2 DiT model: shapes, conditioning, differentiability, training step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile import train as train_mod


@pytest.fixture(scope="module")
def small():
    cfg = model_mod.DiTConfig(dim=32, tokens=8, width=32, heads=2, depth=1)
    params = model_mod.init_params(cfg, seed=1)
    return cfg, params


def test_forward_shapes(small):
    cfg, params = small
    x = jnp.zeros((5, cfg.dim), dtype=jnp.float32)
    t = jnp.full((5,), 0.5, dtype=jnp.float32)
    y = model_mod.forward(params, cfg, x, t)
    assert y.shape == (5, cfg.dim)
    assert np.isfinite(np.asarray(y)).all()


def test_time_conditioning_matters(small):
    cfg, params = small
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, cfg.dim)), dtype=jnp.float32)
    y1 = model_mod.forward(params, cfg, x, jnp.full((3,), 0.1, jnp.float32))
    y2 = model_mod.forward(params, cfg, x, jnp.full((3,), 0.9, jnp.float32))
    assert not np.allclose(np.asarray(y1), np.asarray(y2))


def test_batch_rows_independent(small):
    cfg, params = small
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, cfg.dim)), dtype=jnp.float32)
    t = jnp.full((4,), 0.3, jnp.float32)
    full = model_mod.forward(params, cfg, x, t)
    row = model_mod.forward(params, cfg, x[1:2], t[1:2])
    np.testing.assert_allclose(np.asarray(full)[1], np.asarray(row)[0],
                               rtol=1e-4, atol=1e-5)


def test_gradients_flow(small):
    cfg, params = small
    rng = np.random.default_rng(2)
    x0 = jnp.asarray(rng.normal(size=(4, cfg.dim)), dtype=jnp.float32)
    t = jnp.asarray(rng.uniform(0.01, 1.0, size=4), dtype=jnp.float32)
    eps = jnp.asarray(rng.normal(size=(4, cfg.dim)), dtype=jnp.float32)
    loss, grads = jax.value_and_grad(train_mod.dsm_loss)(params, cfg, x0, t, eps)
    assert np.isfinite(float(loss))
    norms = [float(jnp.abs(g).max()) for g in jax.tree_util.tree_leaves(grads)]
    assert max(norms) > 0.0, "no gradient reached any parameter"


def test_param_count_reasonable():
    cfg = model_mod.DiTConfig()
    params = model_mod.init_params(cfg)
    n = model_mod.param_count(params)
    assert 10_000 < n < 2_000_000, n


def test_short_training_reduces_loss():
    cfg = model_mod.DiTConfig(dim=32, tokens=8, width=32, heads=2, depth=1)
    params, _cfg, _data, history = train_mod.train(
        cfg=cfg, steps=30, batch=64, seed=3, verbose=False
    )
    head = np.mean(history[:5])
    tail = np.mean(history[-5:])
    assert tail < head * 0.9, f"loss did not decrease: {head} -> {tail}"


def test_schedule_constants_match_rust():
    # alpha² + sigma² = 1 and endpoint values of the VP-linear schedule.
    for t in [1e-3, 0.3, 1.0]:
        a, s = train_mod.alpha_sigma(jnp.asarray(t))
        assert abs(float(a) ** 2 + float(s) ** 2 - 1.0) < 1e-6
    a1, _ = train_mod.alpha_sigma(jnp.asarray(1.0))
    # log alpha(1) = -0.25*(19.9) - 0.05 = -5.025
    assert abs(float(jnp.log(a1)) + 5.025) < 1e-4
