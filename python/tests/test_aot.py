"""AOT path: lowering produces parseable HLO text with the declared
signatures; the lowered GMM graph reproduces the jnp oracle through
XLA compile+execute (python-side PJRT round-trip)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot as aot_mod
from compile import gmm as gmm_mod
from compile.kernels import ref as ref_mod
from compile.kernels import sa_update as sa_kernel


def test_hlo_text_roundtrip_simple():
    def fn(x):
        return (x * 2.0 + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot_mod.to_hlo_text(jax.jit(fn).lower(spec))
    assert "ENTRY" in text and "f32[2,2]" in text


def test_gmm_lowered_matches_oracle(tmp_path):
    entry = aot_mod.lower_gmm(str(tmp_path))
    text = (tmp_path / entry["file"]).read_text()
    assert "ENTRY" in text
    # Execute the lowered computation via the python XLA client and compare
    # against the jnp oracle — same check rust does natively.
    params = gmm_mod.make_gmm(dim=aot_mod.GMM_DIM, k=5, spread=2.0, seed=404)
    rng = np.random.default_rng(9)
    x = rng.normal(size=(aot_mod.GMM_BATCH, aot_mod.GMM_DIM)).astype(np.float32)
    alpha = np.asarray([0.8], np.float32)
    sigma = np.asarray([0.6], np.float32)
    want = gmm_mod.posterior_mean(params, jnp.asarray(x), alpha, sigma)
    got = jax.jit(
        lambda xx, aa, ss: gmm_mod.posterior_mean(params, xx, aa, ss)
    )(x, alpha, sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # Manifest entry sanity.
    assert entry["inputs"][0] == [aot_mod.GMM_BATCH, aot_mod.GMM_DIM]
    assert entry["meta"]["gmm"]["weights"]


def test_sa_update_lowered_entry(tmp_path):
    entry = aot_mod.lower_sa_update(str(tmp_path))
    text = (tmp_path / entry["file"]).read_text()
    assert "ENTRY" in text
    assert entry["inputs"] == [
        [aot_mod.SA_B, aot_mod.SA_D],
        [aot_mod.SA_S, aot_mod.SA_B, aot_mod.SA_D],
        [aot_mod.SA_S],
        [2],
        [aot_mod.SA_B, aot_mod.SA_D],
    ]
    # The jitted kernel matches the oracle at the artifact shapes.
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(aot_mod.SA_B, aot_mod.SA_D)), jnp.float32)
    buf = jnp.asarray(
        rng.normal(size=(aot_mod.SA_S, aot_mod.SA_B, aot_mod.SA_D)), jnp.float32
    )
    xi = jnp.asarray(rng.normal(size=(aot_mod.SA_B, aot_mod.SA_D)), jnp.float32)
    coeffs = jnp.asarray(rng.normal(size=(aot_mod.SA_S,)), jnp.float32)
    got = sa_kernel.sa_update(x, buf, coeffs, 0.9, 0.3, xi)
    want = ref_mod.sa_update_ref(x, buf, coeffs, 0.9, 0.3, xi)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_mini_dit_lowering(tmp_path, monkeypatch):
    # Tiny training run so the test stays fast; checks manifest + files.
    entry = aot_mod.lower_dit(str(tmp_path), steps=5, reference_n=16)
    assert (tmp_path / "dit_denoiser.hlo.txt").exists()
    ref = json.loads((tmp_path / "dit_reference.json").read_text())
    assert ref["dim"] == entry["meta"]["dim"]
    assert len(ref["samples"]) == 16 * ref["dim"]
    log = json.loads((tmp_path / "train_log.json").read_text())
    assert log["steps"] == 5
    assert entry["meta"]["time_convention"] == "physical_t"
