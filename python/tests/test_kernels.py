"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps the shape space; fixed-seed numpy drives the values.
This is the CORE correctness signal for the compile path (the same kernel
code is lowered into the AOT artifacts).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_mod
from compile.kernels import ref as ref_mod
from compile.kernels import sa_update as sa_mod

RNG = np.random.default_rng(0)


def _rand(*shape):
    return jnp.asarray(RNG.normal(size=shape), dtype=jnp.float32)


class TestSaUpdate:
    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 9),
        d=st.integers(1, 200),
        s=st.integers(1, 4),
        block=st.sampled_from([8, 32, 128]),
    )
    def test_matches_ref_across_shapes(self, b, d, s, block):
        x = _rand(b, d)
        buf = _rand(s, b, d)
        xi = _rand(b, d)
        coeffs = _rand(s)
        c0, sig = 0.73, 0.21
        got = sa_mod.sa_update(x, buf, coeffs, c0, sig, xi, block_d=block)
        want = ref_mod.sa_update_ref(x, buf, coeffs, c0, sig, xi)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_zero_coeffs_is_affine_in_x(self):
        x = _rand(4, 32)
        buf = jnp.zeros((2, 4, 32), dtype=jnp.float32)
        xi = _rand(4, 32)
        got = sa_mod.sa_update(x, buf, jnp.zeros(2), 2.0, 0.5, xi)
        np.testing.assert_allclose(got, 2.0 * x + 0.5 * xi, rtol=1e-6, atol=1e-6)

    def test_padding_path(self):
        # d not a multiple of block_d exercises the pad/crop branch.
        x = _rand(3, 130)
        buf = _rand(2, 3, 130)
        xi = _rand(3, 130)
        coeffs = _rand(2)
        got = sa_mod.sa_update(x, buf, coeffs, 1.0, 0.0, xi, block_d=128)
        want = ref_mod.sa_update_ref(x, buf, coeffs, 1.0, 0.0, xi)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_linearity_property(self):
        # out(a·coeffs) − out(0) is linear in a.
        x = _rand(2, 16)
        buf = _rand(3, 2, 16)
        xi = jnp.zeros((2, 16), dtype=jnp.float32)
        c = _rand(3)
        base = sa_mod.sa_update(x, buf, 0.0 * c, 1.0, 0.0, xi)
        one = sa_mod.sa_update(x, buf, c, 1.0, 0.0, xi)
        two = sa_mod.sa_update(x, buf, 2.0 * c, 1.0, 0.0, xi)
        np.testing.assert_allclose(two - base, 2.0 * (one - base), rtol=1e-4, atol=1e-5)

    def test_vmem_estimate_positive(self):
        assert sa_mod.vmem_bytes(32, 64, 4) > 0


class TestAttention:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 4),
        h=st.integers(1, 4),
        l=st.sampled_from([1, 3, 16, 40]),
        dh=st.sampled_from([4, 16, 32]),
    )
    def test_matches_ref_across_shapes(self, b, h, l, dh):
        q, k, v = _rand(b, h, l, dh), _rand(b, h, l, dh), _rand(b, h, l, dh)
        got = attn_mod.attention(q, k, v)
        want = ref_mod.mha_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_softmax_rows_are_convex_combinations(self):
        # With v = identity-ish rows, output rows stay within value hull:
        # max(out) ≤ max(v), min(out) ≥ min(v).
        q, k = _rand(1, 1, 8, 8), _rand(1, 1, 8, 8)
        v = _rand(1, 1, 8, 8)
        out = np.asarray(attn_mod.attention(q, k, v))
        assert out.max() <= float(np.asarray(v).max()) + 1e-5
        assert out.min() >= float(np.asarray(v).min()) - 1e-5

    def test_permutation_equivariance(self):
        # Permuting the key/value positions leaves the output unchanged.
        q, k, v = _rand(1, 2, 6, 8), _rand(1, 2, 6, 8), _rand(1, 2, 6, 8)
        perm = np.array([3, 1, 5, 0, 2, 4])
        a = attn_mod.attention(q, k, v)
        b = attn_mod.attention(q, k[:, :, perm], v[:, :, perm])
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_large_logits_stable(self):
        q = 30.0 * _rand(1, 1, 4, 8)
        k = 30.0 * _rand(1, 1, 4, 8)
        v = _rand(1, 1, 4, 8)
        out = np.asarray(attn_mod.attention(q, k, v))
        assert np.isfinite(out).all()

    def test_perf_estimates(self):
        assert attn_mod.vmem_bytes(16, 16) > 0
        u = attn_mod.mxu_utilization_estimate(16, 16)
        assert 0.0 < u <= 1.0


class TestAttentionBackward:
    """The custom-VJP backward Pallas kernel vs jax.grad of the oracle."""

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 3),
        l=st.sampled_from([2, 8, 17]),
        dh=st.sampled_from([4, 16]),
    )
    def test_grads_match_ref(self, b, h, l, dh):
        import jax

        q, k, v = _rand(b, h, l, dh), _rand(b, h, l, dh), _rand(b, h, l, dh)
        w = _rand(b, h, l, dh)  # random cotangent direction via weighted sum
        f = lambda q, k, v: jnp.sum(w * attn_mod.attention(q, k, v))
        g = lambda q, k, v: jnp.sum(w * ref_mod.mha_ref(q, k, v))
        ga = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gb = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a_, b_ in zip(ga, gb):
            np.testing.assert_allclose(a_, b_, rtol=5e-4, atol=5e-5)

    def test_grad_through_jit(self):
        import jax

        q, k, v = _rand(1, 2, 8, 8), _rand(1, 2, 8, 8), _rand(1, 2, 8, 8)
        f = jax.jit(lambda q, k, v: jnp.sum(attn_mod.attention(q, k, v) ** 2))
        val, grads = jax.value_and_grad(f, argnums=(0, 1, 2))(q, k, v)
        assert np.isfinite(float(val))
        assert all(np.isfinite(np.asarray(g)).all() for g in grads)
