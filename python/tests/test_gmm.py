"""Layer-2 GMM oracle vs a direct numpy implementation."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import gmm as gmm_mod


def numpy_posterior_mean(p, x, alpha, sigma):
    """Independent numpy reference (different code path from jnp)."""
    var = alpha**2 * p.variances + sigma**2  # [K, D]
    out = np.zeros_like(x)
    for i, xi in enumerate(x):
        diff = xi[None, :] - alpha * p.means  # [K, D]
        logp = -0.5 * np.sum(np.log(2 * np.pi * var) + diff**2 / var, axis=1)
        logp += np.log(p.weights)
        g = np.exp(logp - logp.max())
        g /= g.sum()
        mk = p.means + (alpha * p.variances / var) * diff
        out[i] = (g[:, None] * mk).sum(axis=0)
    return out


@pytest.fixture(scope="module")
def params():
    return gmm_mod.make_gmm(dim=6, k=4, spread=2.0, seed=11)


def test_posterior_mean_matches_numpy(params):
    rng = np.random.default_rng(3)
    x = rng.normal(size=(12, 6)).astype(np.float32)
    for alpha, sigma in [(0.99, 0.05), (0.7, 0.7), (0.05, 1.0)]:
        got = gmm_mod.posterior_mean(
            params, jnp.asarray(x), jnp.asarray([alpha]), jnp.asarray([sigma])
        )
        want = numpy_posterior_mean(params, x.astype(np.float64), alpha, sigma)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_posterior_collapses_at_low_noise(params):
    # sigma→0, alpha→1: E[x0|x] → x when x is in-support.
    x = params.means[:1].astype(np.float32)
    got = gmm_mod.posterior_mean(
        params, jnp.asarray(x), jnp.asarray([1.0]), jnp.asarray([1e-3])
    )
    np.testing.assert_allclose(np.asarray(got), x, rtol=1e-2, atol=1e-2)


def test_posterior_goes_to_prior_mean_at_high_noise(params):
    # sigma→∞: responsibilities → weights, gains → 0 ⇒ E[x0|x] → Σ w_k mu_k.
    x = np.zeros((1, 6), dtype=np.float32)
    got = gmm_mod.posterior_mean(
        params, jnp.asarray(x), jnp.asarray([1e-4]), jnp.asarray([50.0])
    )
    want = (params.weights[:, None] * params.means).sum(axis=0)
    np.testing.assert_allclose(np.asarray(got)[0], want, rtol=1e-3, atol=1e-3)


def test_sampler_moments(params):
    xs = gmm_mod.sample_prior(params, 20000, seed=5)
    want_mean = (params.weights[:, None] * params.means).sum(axis=0)
    np.testing.assert_allclose(xs.mean(axis=0), want_mean, atol=0.06)


def test_manifest_roundtrip(params):
    m = params.to_manifest()
    assert np.allclose(m["weights"], params.weights)
    assert len(m["means"]) == 4 and len(m["means"][0]) == 6
