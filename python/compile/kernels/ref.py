"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1).

Every kernel in this package must match its oracle to float32 tolerance
across the shape/dtype sweeps in python/tests/ — this is the CORE
correctness signal for the compile path.
"""

import jax.numpy as jnp


def sa_update_ref(x, buf, coeffs, c0, sigma_tilde, xi):
    """Reference for the fused SA-Solver update (Eq. (14)/(17)):

        out = c0 * x + sum_s coeffs[s] * buf[s] + sigma_tilde * xi

    Args:
      x:           [B, D] current state.
      buf:         [S, B, D] stacked model evaluations (zero-padded rows
                   beyond the active order carry coeffs[s] = 0).
      coeffs:      [S] Adams coefficients b_j.
      c0:          scalar carry coefficient.
      sigma_tilde: scalar injected-noise std.
      xi:          [B, D] standard normal draws.
    """
    weighted = jnp.tensordot(coeffs, buf, axes=1)  # [B, D]
    return c0 * x + weighted + sigma_tilde * xi


def attention_ref(q, k, v):
    """Reference single-head scaled-dot-product attention.

    Args:
      q, k, v: [L, Dh].
    Returns:
      [L, Dh].
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = (q @ k.T) * scale
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def mha_ref(q, k, v):
    """Multi-head reference: q, k, v are [B, H, L, Dh]."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.einsum("bhld,bhmd->bhlm", q, k) * scale
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhlm,bhmd->bhld", p, v)
