"""Pallas kernel: fused SA-Solver state update (Layer 1).

The per-step update of Eqs. (14)/(17),

    out = c0 * x + sum_s b[s] * buf[s] + sigma_tilde * xi,

is bandwidth-bound: naively composed it reads/writes state-sized tensors
S + 3 times. The kernel fuses everything into a single pass: each grid
step owns one (block_b, block_d) tile of the state; the S buffer slabs for
that tile are resident in VMEM, so every HBM element is touched exactly
once.

TPU framing (DESIGN.md §3): tiles are padded to (8, 128) VPU lanes; the
buffer axis S is the innermost reduction and stays register/VMEM-local.
There is no contraction, so the MXU is idle by design — the roofline is
HBM bandwidth; the fused pass is the optimum up to constant factors.

CPU note: must run interpret=True — the Mosaic custom-call emitted for
real TPUs cannot execute on the CPU PJRT plugin.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, buf_ref, coef_ref, scal_ref, xi_ref, o_ref, *, n_buf):
    """One tile: out = c0*x + Σ_s b_s·buf_s + σ̃·ξ, single fused pass."""
    c0 = scal_ref[0]
    sigma_tilde = scal_ref[1]
    acc = c0 * x_ref[...] + sigma_tilde * xi_ref[...]
    # Static unroll over the (small, fixed) buffer order.
    for s in range(n_buf):
        acc += coef_ref[s] * buf_ref[s]
    o_ref[...] = acc


def sa_update(x, buf, coeffs, c0, sigma_tilde, xi, *, block_d=128, interpret=True):
    """Fused SA update via Pallas.

    Args:
      x:      [B, D] float32 current state.
      buf:    [S, B, D] float32 stacked model evaluations.
      coeffs: [S] float32 Adams coefficients.
      c0, sigma_tilde: scalars (python float or 0-d array).
      xi:     [B, D] float32 noise.
      block_d: tile width along D (clipped to D).
      interpret: run the interpreter (required on CPU).

    Returns:
      [B, D] float32.
    """
    b, d = x.shape
    s = buf.shape[0]
    assert buf.shape == (s, b, d), buf.shape
    assert coeffs.shape == (s,), coeffs.shape
    block_d = min(block_d, d)
    # Pad D so the grid tiles exactly.
    pad = (-d) % block_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        xi = jnp.pad(xi, ((0, 0), (0, pad)))
        buf = jnp.pad(buf, ((0, 0), (0, 0), (0, pad)))
    dp = d + pad
    scal = jnp.stack([
        jnp.asarray(c0, dtype=x.dtype),
        jnp.asarray(sigma_tilde, dtype=x.dtype),
    ])
    grid = (dp // block_d,)
    out = pl.pallas_call(
        functools.partial(_kernel, n_buf=s),
        out_shape=jax.ShapeDtypeStruct((b, dp), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, block_d), lambda j: (0, j)),          # x tile
            pl.BlockSpec((s, b, block_d), lambda j: (0, 0, j)),    # buffer slab
            pl.BlockSpec((s,), lambda j: (0,)),                    # coefficients
            pl.BlockSpec((2,), lambda j: (0,)),                    # c0, sigma
            pl.BlockSpec((b, block_d), lambda j: (0, j)),          # xi tile
        ],
        out_specs=pl.BlockSpec((b, block_d), lambda j: (0, j)),
        interpret=interpret,
    )(x, buf, coeffs, scal, xi)
    return out[:, :d]


def vmem_bytes(b, d, s, block_d=128, dtype_bytes=4):
    """Estimated VMEM footprint per grid step (DESIGN.md §Perf): the x, xi
    and out tiles plus the S buffer slabs and scalars."""
    tile = b * min(block_d, d) * dtype_bytes
    return tile * (3 + s) + (s + 2) * dtype_bytes
