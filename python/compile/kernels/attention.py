"""Pallas kernels: fused scaled-dot-product attention, forward AND
backward (Layer 1).

The DiT denoiser's hot spot. One grid step per (batch, head): the L×L
score matrix is formed, soft-maxed and contracted against V entirely in
VMEM — it never round-trips to HBM (the TPU analog of flash-attention's
shared-memory tiling; see DESIGN.md §3). At this model's sizes
(L ≤ 64, Dh ≤ 32) a whole head fits one block, so no online-softmax
streaming is needed; the q/k/v tiles feed the MXU via jnp.dot.

`pallas_call` has no automatic reverse-mode derivative, so training wires
a `jax.custom_vjp`: the backward pass is a *second* Pallas kernel
implementing the standard attention gradients

    P  = softmax(QKᵀ·s)          dV = Pᵀ dO
    dP = dO Vᵀ                   dS = P ∘ (dP − rowsum(dP ∘ P))
    dQ = dS K · s                dK = dSᵀ Q · s

validated against jax.grad of the jnp reference in python/tests.

CPU note: interpret=True required throughout — the Mosaic custom-call
emitted for real TPUs cannot execute on the CPU PJRT plugin.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # [L, Dh] — leading grid axis is (batch·head)
    k = k_ref[0]
    v = v_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.dot(q, k.T) * scale                     # MXU contraction
    m = jnp.max(scores, axis=-1, keepdims=True)          # stable softmax
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v)                             # MXU contraction


def _bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    scores = jnp.dot(q, k.T) * scale
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)           # [L, L]
    dv = jnp.dot(p.T, do)                                # [L, Dh]
    dp = jnp.dot(do, v.T)                                # [L, L]
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[0] = jnp.dot(ds, k) * scale
    dk_ref[0] = jnp.dot(ds.T, q) * scale
    dv_ref[0] = dv


def _flat_specs(l, dh):
    return [
        pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0)),
    ]


def _forward_flat(qf, kf, vf):
    bh, l, dh = qf.shape
    return pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((bh, l, dh), qf.dtype),
        grid=(bh,),
        in_specs=_flat_specs(l, dh),
        out_specs=pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0)),
        interpret=True,
    )(qf, kf, vf)


def _backward_flat(qf, kf, vf, dof):
    bh, l, dh = qf.shape
    shape = jax.ShapeDtypeStruct((bh, l, dh), qf.dtype)
    spec = pl.BlockSpec((1, l, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _bwd_kernel,
        out_shape=(shape, shape, shape),
        grid=(bh,),
        in_specs=_flat_specs(l, dh) + [spec],
        out_specs=(spec, spec, spec),
        interpret=True,
    )(qf, kf, vf, dof)


@jax.custom_vjp
def _attention_core(qf, kf, vf):
    return _forward_flat(qf, kf, vf)


def _core_fwd(qf, kf, vf):
    return _forward_flat(qf, kf, vf), (qf, kf, vf)


def _core_bwd(res, dof):
    qf, kf, vf = res
    return _backward_flat(qf, kf, vf, dof)


_attention_core.defvjp(_core_fwd, _core_bwd)


def attention(q, k, v, *, interpret=True):
    """Multi-head attention via Pallas (differentiable via custom VJP).

    Args:
      q, k, v: [B, H, L, Dh] float32.
      interpret: must stay True on CPU (kept in the signature to document
        the real-TPU switch point).
    Returns:
      [B, H, L, Dh] float32.
    """
    assert interpret, "real-TPU Mosaic lowering cannot run on the CPU PJRT plugin"
    b, h, l, dh = q.shape
    qf = q.reshape(b * h, l, dh)
    kf = k.reshape(b * h, l, dh)
    vf = v.reshape(b * h, l, dh)
    return _attention_core(qf, kf, vf).reshape(b, h, l, dh)


def vmem_bytes(l, dh, dtype_bytes=4):
    """Per-step VMEM estimate: q, k, v, out tiles plus the L×L score matrix
    (twice, for the exp buffer)."""
    return 4 * l * dh * dtype_bytes + 2 * l * l * dtype_bytes


def mxu_utilization_estimate(l, dh):
    """Fraction of MXU-shaped work vs. padded 128×128 tiles — the lowering
    pads L and Dh up to lane multiples; tiny heads underutilize the array.
    Reported in DESIGN.md §Perf; interpret-mode wallclock is *not* a TPU
    proxy."""
    pad = lambda n, m: ((n + m - 1) // m) * m
    real = 2 * l * l * dh
    padded = 2 * pad(l, 128) * pad(l, 128) * pad(dh, 128)
    return real / padded
