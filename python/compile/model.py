"""Layer 2: the DiT-style denoiser (fwd graph), calling the Layer-1 Pallas
attention kernel.

A small diffusion transformer over D = T·F flattened data:
token embed → [AdaLN-modulated block: MHA (Pallas) + MLP] × depth →
AdaLN final layer → data-prediction head. Time conditioning follows DiT:
sinusoidal embedding → MLP → per-block (scale, shift, gate).

Everything is pure functions over an explicit parameter pytree so the
trained closure lowers cleanly to one HLO graph with weights baked in.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as attn_kernel


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    dim: int = 64          # flattened data dimension
    tokens: int = 16       # sequence length T
    width: int = 64        # model width
    heads: int = 4
    depth: int = 2
    mlp_ratio: int = 2
    time_freqs: int = 16   # sinusoidal time features / 2

    @property
    def feat(self):
        assert self.dim % self.tokens == 0
        return self.dim // self.tokens

    @property
    def head_dim(self):
        assert self.width % self.heads == 0
        return self.width // self.heads


def init_params(cfg: DiTConfig, seed=0):
    """Xavier-ish init of the full parameter pytree (numpy for portability)."""
    rng = np.random.default_rng(seed)

    def dense(din, dout, scale=None):
        s = scale if scale is not None else (2.0 / (din + dout)) ** 0.5
        return {
            "w": rng.normal(0.0, s, size=(din, dout)).astype(np.float32),
            "b": np.zeros(dout, dtype=np.float32),
        }

    w = cfg.width
    params = {
        "token_embed": dense(cfg.feat, w),
        "pos_embed": (0.02 * rng.normal(size=(cfg.tokens, w))).astype(np.float32),
        "time_mlp1": dense(2 * cfg.time_freqs, w),
        "time_mlp2": dense(w, w),
        "blocks": [],
        # Final AdaLN + head.
        "final_mod": dense(w, 2 * w, scale=1e-3),
        "head": dense(w, cfg.feat, scale=1e-3),
    }
    for _ in range(cfg.depth):
        params["blocks"].append({
            "qkv": dense(w, 3 * w),
            "proj": dense(w, w),
            "mlp1": dense(w, cfg.mlp_ratio * w),
            "mlp2": dense(cfg.mlp_ratio * w, w),
            # AdaLN modulation: 6 chunks (shift/scale/gate × attn/mlp).
            "mod": dense(w, 6 * w, scale=1e-3),
        })
    return jax.tree_util.tree_map(jnp.asarray, params)


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _layer_norm(x, eps=1e-6):
    m = x.mean(axis=-1, keepdims=True)
    v = ((x - m) ** 2).mean(axis=-1, keepdims=True)
    return (x - m) / jnp.sqrt(v + eps)


def time_embedding(t, cfg: DiTConfig):
    """Sinusoidal features of physical time t: [B] → [B, 2·time_freqs]."""
    freqs = jnp.exp(jnp.linspace(0.0, jnp.log(1000.0), cfg.time_freqs))
    ang = t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def forward(params, cfg: DiTConfig, x, t, *, interpret=True):
    """Data-prediction forward pass.

    Args:
      x: [B, dim] noisy state x_t.
      t: [B] physical time.
    Returns:
      x0hat: [B, dim].
    """
    b = x.shape[0]
    tokens = x.reshape(b, cfg.tokens, cfg.feat)
    h = _dense(params["token_embed"], tokens) + params["pos_embed"][None]

    temb = time_embedding(t, cfg)
    c = jax.nn.silu(_dense(params["time_mlp1"], temb))
    c = jax.nn.silu(_dense(params["time_mlp2"], c))  # [B, W]

    for blk in params["blocks"]:
        mod = _dense(blk["mod"], c)  # [B, 6W]
        (sh_a, sc_a, g_a, sh_m, sc_m, g_m) = jnp.split(mod, 6, axis=-1)
        # --- attention sub-block
        hn = _layer_norm(h) * (1.0 + sc_a[:, None]) + sh_a[:, None]
        qkv = _dense(blk["qkv"], hn)  # [B, T, 3W]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        def heads(z):
            return z.reshape(b, cfg.tokens, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)
        a = attn_kernel.attention(heads(q), heads(k), heads(v), interpret=interpret)
        a = a.transpose(0, 2, 1, 3).reshape(b, cfg.tokens, cfg.width)
        h = h + g_a[:, None] * _dense(blk["proj"], a)
        # --- MLP sub-block
        hn = _layer_norm(h) * (1.0 + sc_m[:, None]) + sh_m[:, None]
        z = jax.nn.gelu(_dense(blk["mlp1"], hn))
        h = h + g_m[:, None] * _dense(blk["mlp2"], z)

    mod = _dense(params["final_mod"], c)
    sh, sc = jnp.split(mod, 2, axis=-1)
    h = _layer_norm(h) * (1.0 + sc[:, None]) + sh[:, None]
    out = _dense(params["head"], h)  # [B, T, F]
    return out.reshape(b, cfg.dim)


def param_count(params):
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(np.prod(l.shape)) for l in leaves)
