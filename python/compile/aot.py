"""AOT lowering (the only python entry point — build-time, never on the
request path).

Emits, into --out-dir (default ../artifacts):
  gmm_denoiser.hlo.txt   exact GMM posterior-mean denoiser,
                         inputs (x[B,D], alpha[1], sigma[1])
  dit_denoiser.hlo.txt   trained tiny DiT (weights baked as constants),
                         inputs (x[B,D], t[B])
  sa_update.hlo.txt      fused Pallas SA update,
                         inputs (x[B,D], buf[S,B,D], coeffs[S], scal[2], xi[B,D])
  dit_reference.json     fresh samples of the DiT training distribution
  train_log.json         DSM loss curve of the build-time training run
  manifest.json          shapes + metadata for rust/src/runtime::Registry

Interchange format is HLO **text**, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 (behind the `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. Lower with return_tuple=True and
unwrap with `to_tuple()` on the rust side.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import gmm as gmm_mod
from . import model as model_mod
from . import train as train_mod
from .kernels import sa_update as sa_kernel

GMM_BATCH, GMM_DIM = 64, 16
DIT_BATCH = 32
SA_S, SA_B, SA_D = 4, 32, 64


def to_hlo_text(lowered):
    """stablehlo → XlaComputation → HLO text (see module docstring).

    `as_hlo_text()` elides non-scalar constants as `{...}`, which the 0.5.1
    text parser silently reads as zeros — fatal for artifacts with baked
    weights. Print through HloPrintOptions with print_large_constants.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-jax metadata attributes (source_end_line etc.) are unknown to the
    # 0.5.1 text parser; strip metadata entirely.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived HLO printing"
    return text


def lower_gmm(out_dir):
    params = gmm_mod.make_gmm(dim=GMM_DIM, k=5, spread=2.0, seed=404)

    def fn(x, alpha, sigma):
        return (gmm_mod.posterior_mean(params, x, alpha, sigma),)

    spec_x = jax.ShapeDtypeStruct((GMM_BATCH, GMM_DIM), jnp.float32)
    spec_s = jax.ShapeDtypeStruct((1,), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec_x, spec_s, spec_s))
    path = os.path.join(out_dir, "gmm_denoiser.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": "gmm_denoiser",
        "file": "gmm_denoiser.hlo.txt",
        "inputs": [[GMM_BATCH, GMM_DIM], [1], [1]],
        "outputs": [[GMM_BATCH, GMM_DIM]],
        "meta": {
            "time_convention": "alpha_sigma",
            "dim": GMM_DIM,
            "batch": GMM_BATCH,
            "gmm": params.to_manifest(),
        },
    }
    print(f"[aot] gmm_denoiser: {len(text)} chars")
    return entry


def lower_dit(out_dir, steps, reference_n=512):
    params, cfg, data, history = train_mod.train(steps=steps, verbose=True)

    def fn(x, t):
        return (model_mod.forward(params, cfg, x, t, interpret=True),)

    spec_x = jax.ShapeDtypeStruct((DIT_BATCH, cfg.dim), jnp.float32)
    spec_t = jax.ShapeDtypeStruct((DIT_BATCH,), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec_x, spec_t))
    with open(os.path.join(out_dir, "dit_denoiser.hlo.txt"), "w") as f:
        f.write(text)

    reference = gmm_mod.sample_prior(data, reference_n, seed=777)
    with open(os.path.join(out_dir, "dit_reference.json"), "w") as f:
        json.dump({"dim": cfg.dim, "samples": np.asarray(reference).ravel().tolist()}, f)
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump({
            "steps": len(history),
            "loss": history,
            "param_count": model_mod.param_count(params),
        }, f)

    entry = {
        "name": "dit_denoiser",
        "file": "dit_denoiser.hlo.txt",
        "inputs": [[DIT_BATCH, cfg.dim], [DIT_BATCH]],
        "outputs": [[DIT_BATCH, cfg.dim]],
        "meta": {
            "time_convention": "physical_t",
            "dim": cfg.dim,
            "batch": DIT_BATCH,
            "schedule": "vp_linear",
            "train_steps": steps,
            "param_count": model_mod.param_count(params),
            "gmm": data.to_manifest(),
        },
    }
    print(f"[aot] dit_denoiser: {len(text)} chars, "
          f"{model_mod.param_count(params)} params, final loss {history[-1]:.4f}")
    return entry


def lower_sa_update(out_dir):
    def fn(x, buf, coeffs, scal, xi):
        return (
            sa_kernel.sa_update(x, buf, coeffs, scal[0], scal[1], xi, interpret=True),
        )

    specs = [
        jax.ShapeDtypeStruct((SA_B, SA_D), jnp.float32),
        jax.ShapeDtypeStruct((SA_S, SA_B, SA_D), jnp.float32),
        jax.ShapeDtypeStruct((SA_S,), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.float32),
        jax.ShapeDtypeStruct((SA_B, SA_D), jnp.float32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(os.path.join(out_dir, "sa_update.hlo.txt"), "w") as f:
        f.write(text)
    entry = {
        "name": "sa_update",
        "file": "sa_update.hlo.txt",
        "inputs": [[SA_B, SA_D], [SA_S, SA_B, SA_D], [SA_S], [2], [SA_B, SA_D]],
        "outputs": [[SA_B, SA_D]],
        "meta": {"s": SA_S, "batch": SA_B, "dim": SA_D, "kind": "fused_update"},
    }
    print(f"[aot] sa_update: {len(text)} chars")
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--train-steps",
        type=int,
        default=int(os.environ.get("SADIFF_TRAIN_STEPS", "400")),
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = [
        lower_gmm(args.out_dir),
        lower_sa_update(args.out_dir),
        lower_dit(args.out_dir, steps=args.train_steps),
    ]
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": entries}, f, indent=1)
    print(f"[aot] wrote manifest with {len(entries)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
