"""Layer 2: analytic GMM posterior-mean denoiser in jnp.

Mirrors `rust/src/gmm`: for x0 ~ Σ w_k N(mu_k, diag(s_k)) and
x_t | x0 ~ N(alpha x0, sigma² I), the exact data-prediction target is

    E[x0 | x_t] = Σ_k γ_k(x_t) · (mu_k + alpha s_k / (alpha² s_k + sigma²) (x_t − alpha mu_k))

with responsibilities γ_k ∝ w_k N(x_t; alpha mu_k, alpha² s_k + sigma²).

The AOT artifact exports this with (alpha, sigma) as runtime inputs so one
compiled executable serves every schedule/timestep; the GMM parameters are
baked as constants and recorded in the manifest so the Rust side can
reconstruct the identical mixture for cross-validation.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GmmParams:
    weights: np.ndarray  # [K]
    means: np.ndarray    # [K, D]
    variances: np.ndarray  # [K, D], diagonal

    @property
    def dim(self):
        return self.means.shape[1]

    def to_manifest(self):
        return {
            "weights": self.weights.tolist(),
            "means": self.means.tolist(),
            "vars": self.variances.tolist(),
        }


def make_gmm(dim, k, spread, seed):
    """Reproducible structured mixture (numpy RNG; parameters are exported
    through the manifest rather than by porting the Rust RNG)."""
    rng = np.random.default_rng(seed)
    raw = rng.normal(size=(k, dim))
    means = spread * raw / np.maximum(np.linalg.norm(raw, axis=1, keepdims=True), 1e-9)
    variances = rng.uniform(0.05, 0.35, size=(k, dim))
    weights = rng.uniform(0.5, 1.5, size=(k,))
    weights = weights / weights.sum()
    return GmmParams(
        weights=weights.astype(np.float64),
        means=means.astype(np.float64),
        variances=variances.astype(np.float64),
    )


def posterior_mean(params: GmmParams, x, alpha, sigma):
    """E[x0 | x_t = x] for a batch x: [B, D]; alpha/sigma: scalars ([1])."""
    w = jnp.asarray(params.weights, dtype=x.dtype)        # [K]
    mu = jnp.asarray(params.means, dtype=x.dtype)         # [K, D]
    s = jnp.asarray(params.variances, dtype=x.dtype)      # [K, D]
    alpha = jnp.reshape(alpha, ())
    sigma = jnp.reshape(sigma, ())
    var = alpha * alpha * s + sigma * sigma               # [K, D]
    diff = x[:, None, :] - alpha * mu[None, :, :]         # [B, K, D]
    log_norm = -0.5 * (jnp.log(2.0 * jnp.pi) + jnp.log(var))  # [K, D]
    logp = jnp.sum(log_norm[None] - 0.5 * diff * diff / var[None], axis=-1)  # [B, K]
    logp = logp + jnp.log(w)[None]
    # stable softmax over components
    m = jnp.max(logp, axis=1, keepdims=True)
    gamma = jnp.exp(logp - m)
    gamma = gamma / jnp.sum(gamma, axis=1, keepdims=True)  # [B, K]
    gain = alpha * s / var                                 # [K, D]
    mk = mu[None] + gain[None] * diff                      # [B, K, D]
    return jnp.sum(gamma[:, :, None] * mk, axis=1)         # [B, D]


def sample_prior(params: GmmParams, n, seed):
    """Numpy sampler for references/tests."""
    rng = np.random.default_rng(seed)
    ks = rng.choice(len(params.weights), size=n, p=params.weights)
    eps = rng.normal(size=(n, params.dim))
    return params.means[ks] + np.sqrt(params.variances[ks]) * eps
