"""Build-time training of the tiny DiT denoiser (Layer 2).

Denoising score matching with the data-prediction target on the VP-linear
schedule (matching `rust/src/schedule::NoiseSchedule::vp_linear`):

    t ~ U(t_min, 1),  x_t = alpha_t x0 + sigma_t eps,
    loss = E || model(x_t, t) − x0 ||²

Training data: a fixed structured GMM (`gmm.make_gmm(dim=64, ...)`), so
the trained network has a known ground-truth target distribution and the
Rust side can score generated samples against fresh draws
(`artifacts/dit_reference.json`).

Optimizer: hand-rolled Adam (optax is not in the image). A few hundred
steps on CPU is enough for a clearly-learned denoiser (loss ≪ variance of
x0); this is the "small real model" of the E2E serving example.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import gmm as gmm_mod
from . import model as model_mod

# VP-linear schedule constants (must match rust/src/schedule).
BETA0, BETA1 = 0.1, 20.0
T_MIN, T_MAX = 1e-3, 1.0


def log_alpha(t):
    return -0.25 * t * t * (BETA1 - BETA0) - 0.5 * t * BETA0


def alpha_sigma(t):
    a = jnp.exp(log_alpha(t))
    return a, jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))


def make_data_gmm(dim=64):
    """The DiT training distribution (parameters exported via manifest)."""
    return gmm_mod.make_gmm(dim=dim, k=6, spread=2.5, seed=2024)


def dsm_loss(params, cfg, x0, t, eps, *, interpret=True):
    a, s = alpha_sigma(t)
    xt = a[:, None] * x0 + s[:, None] * eps
    pred = model_mod.forward(params, cfg, xt, t, interpret=interpret)
    return jnp.mean(jnp.sum((pred - x0) ** 2, axis=-1))


def adam_update(params, grads, m, v, step, lr=2e-3, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        params, m, v,
    )
    return params, m, v


def train(cfg=None, steps=400, batch=128, seed=0, interpret=True, verbose=True):
    """Train and return (params, cfg, data_gmm, loss_history)."""
    cfg = cfg or model_mod.DiTConfig()
    data = make_data_gmm(cfg.dim)
    params = model_mod.init_params(cfg, seed=seed)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    m, v = zeros, jax.tree_util.tree_map(jnp.zeros_like, params)

    loss_grad = jax.jit(
        jax.value_and_grad(
            functools.partial(dsm_loss, interpret=interpret), argnums=0
        ),
        static_argnums=(1,),
    )
    rng = np.random.default_rng(seed + 1)
    history = []
    for step in range(1, steps + 1):
        x0 = jnp.asarray(gmm_mod.sample_prior(data, batch, rng.integers(1 << 31)),
                         dtype=jnp.float32)
        t = jnp.asarray(rng.uniform(T_MIN, T_MAX, size=batch), dtype=jnp.float32)
        eps = jnp.asarray(rng.normal(size=(batch, cfg.dim)), dtype=jnp.float32)
        loss, grads = loss_grad(params, cfg, x0, t, eps)
        params, m, v = adam_update(params, grads, m, v, step)
        history.append(float(loss))
        if verbose and (step % 50 == 0 or step == 1):
            print(f"[train] step {step:4d}  dsm_loss {float(loss):9.4f}")
    return params, cfg, data, history
